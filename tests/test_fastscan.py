"""4-bit fast-scan stack (``code_bits=4``, DESIGN.md §12): paired-byte
nibble_lut_sum vs
the widened int8 reference, 4-bit == 8-bit engine identity (fast-mask
edges included), pallas==jnp parity on non-divisible shapes, sharded
merge identity (subprocess under 4 forced host devices), artifact
bitwise round trips, config validation, and the trainer/encoder m<=16
path.  (Nibble pack/unpack round trips live in
``tests/test_packing_props.py`` as property tests over arbitrary
geometries.)"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebooks as cb
from repro.core import icq as icq_mod
from repro.core.encode import pack_nibbles, unpack_nibbles
from repro.index import (adc_search, build_ivf, build_lut,
                         ivf_two_step_search, lut_sum, nibble_lut_sum,
                         quantize_lut, two_step_search)


def _problem(key, n, nq, K=4, m=16, kf=2, d=8, sigma=1.0):
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


# -------------------------------------------------------- nibble lut sum ----

@pytest.mark.parametrize("K,kf", [(4, 2), (7, 3), (5, 1)])
def test_nibble_lut_sum_matches_widened(key, K, kf):
    """Paired-byte gather over packed codes == plain lut_sum over the
    widened codes — *bitwise* for the quantized path (both accumulate
    the same int8 entries in the same integer width before one rescale),
    and to f32 tolerance for the f32 fallback.  Odd K exercises the
    sentinel column."""
    k2 = jax.random.fold_in(key, K)
    q, codes, C, st = _problem(k2, 211, 6, K=K, kf=kf)
    packed = pack_nibbles(codes, K)
    luts = build_lut(q, C)
    for cb_mask in (None, st.fast_mask):
        want_f = lut_sum(luts, codes.astype(jnp.int32), cb_mask)
        got_f = nibble_lut_sum(luts, packed, K, cb_mask)
        np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                                   atol=1e-5)
        ql = quantize_lut(luts, cb_mask)
        want_q = lut_sum(ql, codes.astype(jnp.int32), cb_mask)
        got_q = nibble_lut_sum(ql, packed, K, cb_mask)
        np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want_q))
    # per-query candidate codes (nq, t, K)
    cand = jax.random.randint(jax.random.fold_in(k2, 9), (6, 8, K), 0, 16)
    ql = quantize_lut(luts, st.fast_mask)
    np.testing.assert_array_equal(
        np.asarray(nibble_lut_sum(ql, pack_nibbles(cand, K), K,
                                  st.fast_mask)),
        np.asarray(lut_sum(ql, cand, st.fast_mask)))


# ------------------------------------------------- 4-bit == 8-bit engine ----

@pytest.mark.parametrize("kf", [1, 3])          # |K_fast| in {1, K-1}
@pytest.mark.parametrize("lut_dtype", ["f32", "int8"])
def test_two_step_4bit_matches_8bit(key, kf, lut_dtype):
    """The nibble-packed engine returns bitwise-identical ids,
    distances, and pass accounting to the 8-bit engine on the same
    codes, at both fast-mask edges."""
    q, codes, C, st = _problem(jax.random.fold_in(key, kf), 317, 7, K=4,
                               kf=kf)
    packed = pack_nibbles(codes, 4)
    r8 = two_step_search(q, codes, C, st, 13, backend="jnp",
                         lut_dtype=lut_dtype)
    r4 = two_step_search(q, packed, C, st, 13, backend="jnp",
                         lut_dtype=lut_dtype, code_bits=4)
    np.testing.assert_array_equal(np.asarray(r4.indices),
                                  np.asarray(r8.indices))
    np.testing.assert_array_equal(np.asarray(r4.distances),
                                  np.asarray(r8.distances))
    assert float(r4.pass_rate) == float(r8.pass_rate)


def test_ivf_4bit_matches_8bit(key):
    q, codes, C, st = _problem(key, 911, 6, K=7, m=16, kf=3, sigma=2.0)
    emb = cb.decode(C, codes)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, 16)
    packed = pack_nibbles(codes, 7)
    r8 = ivf_two_step_search(q, codes, C, st, ivf, 17, 4, backend="jnp",
                             lut_dtype="int8")
    r4 = ivf_two_step_search(q, packed, C, st, ivf, 17, 4, backend="jnp",
                             lut_dtype="int8", code_bits=4)
    np.testing.assert_array_equal(np.asarray(r4.indices),
                                  np.asarray(r8.indices))
    np.testing.assert_array_equal(np.asarray(r4.distances),
                                  np.asarray(r8.distances))


def test_code_bits_validation(key):
    q, codes, C, st = _problem(key, 64, 3, K=4, m=32)
    with pytest.raises(ValueError, match="code_bits"):
        two_step_search(q, codes, C, st, 5, backend="jnp", code_bits=5)
    # m > 16 cannot be nibble-addressed
    with pytest.raises(ValueError, match="16"):
        two_step_search(q, pack_nibbles(codes % 16, 4), C, st, 5,
                        backend="jnp", code_bits=4)


# --------------------------------------------------------------- parity ----

@pytest.mark.parametrize("n,nq,K,m,kf", [
    (257, 5, 7, 16, 3),      # non-divisible n/nq, odd K (sentinel)
    (530, 7, 8, 16, 7),      # |K_fast| = K - 1
])
@pytest.mark.parametrize("lut_dtype", ["f32", "int8"])
def test_two_step_4bit_pallas_matches_jnp(key, n, nq, K, m, kf, lut_dtype):
    """Fast-scan crude kernel == jnp nibble engine at code_bits=4:
    exact ids, 1e-4 distances, identical pass accounting, on tile
    shapes that do not divide the block sizes."""
    q, codes, C, st = _problem(jax.random.fold_in(key, n), n, nq, K=K,
                               m=m, kf=kf)
    packed = pack_nibbles(codes, K)
    topk = 17
    r_j = two_step_search(q, packed, C, st, topk, backend="jnp",
                          lut_dtype=lut_dtype, code_bits=4)
    r_p = two_step_search(q, packed, C, st, topk, backend="pallas",
                          interpret=True, block_q=3, block_n=200,
                          lut_dtype=lut_dtype, code_bits=4)
    np.testing.assert_array_equal(np.asarray(r_p.indices),
                                  np.asarray(r_j.indices))
    np.testing.assert_allclose(np.asarray(r_p.distances),
                               np.asarray(r_j.distances), atol=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-6)


def test_adc_4bit_pallas_matches_jnp(key):
    q, codes, C, st = _problem(key, 300, 6, K=5)
    packed = pack_nibbles(codes, 5)
    r_j = adc_search(q, packed, C, 12, backend="jnp", lut_dtype="int8",
                     code_bits=4)
    r_p = adc_search(q, packed, C, 12, backend="pallas", interpret=True,
                     block_q=4, block_n=128, lut_dtype="int8", code_bits=4)
    np.testing.assert_array_equal(np.asarray(r_j.indices),
                                  np.asarray(r_p.indices))
    np.testing.assert_allclose(np.asarray(r_j.distances),
                               np.asarray(r_p.distances), atol=1e-4)


def test_ivf_4bit_pallas_matches_jnp(key):
    q, codes, C, st = _problem(key, 911, 6, K=7, kf=3, sigma=2.0)
    emb = cb.decode(C, codes)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, 16)
    packed = pack_nibbles(codes, 7)
    r_j = ivf_two_step_search(q, packed, C, st, ivf, 17, 4, backend="jnp",
                              lut_dtype="int8", code_bits=4)
    r_p = ivf_two_step_search(q, packed, C, st, ivf, 17, 4,
                              backend="pallas", interpret=True,
                              block_q=4, block_n=96, lut_dtype="int8",
                              code_bits=4)
    np.testing.assert_array_equal(np.asarray(r_p.indices),
                                  np.asarray(r_j.indices))
    np.testing.assert_allclose(np.asarray(r_p.distances),
                               np.asarray(r_j.distances), atol=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-6)


# ------------------------------------------------------------- sharding ----

_SHARDED_4BIT_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.core.encode import pack_nibbles
    from repro.index import FlatADC, IVFTwoStep, TwoStep

    key = jax.random.PRNGKey(0)
    n, nq, K, m, d, kf = 1237, 9, 7, 16, 8, 3
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    packed = pack_nibbles(codes, K)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    emb = cb.decode(C, codes)
    mesh = jax.make_mesh((4,), ("data",))

    def check(idx, tag):
        r1, r4 = idx.search(q), idx.shard(mesh).search(q)
        np.testing.assert_array_equal(np.asarray(r1.indices),
                                      np.asarray(r4.indices), err_msg=tag)
        np.testing.assert_allclose(np.asarray(r1.distances),
                                   np.asarray(r4.distances), atol=1e-5,
                                   err_msg=tag)
        assert float(r1.pass_rate) == float(r4.pass_rate), tag

    check(FlatADC.build(packed, C, topk=17, backend="jnp",
                        lut_dtype="int8", code_bits=4), "flat-4bit")
    check(TwoStep.build(packed, C, st, topk=17, backend="jnp",
                        lut_dtype="int8", code_bits=4), "two-step-4bit")
    idx = IVFTwoStep.build(packed, C, st, emb_db=emb,
                           key=jax.random.fold_in(key, 3),
                           n_lists=16, n_probe=4, topk=17,
                           backend="jnp", lut_dtype="int8", code_bits=4)
    check(idx, "ivf-4bit")
    print("SHARDED_4BIT_OK")
""")


def test_sharded_4bit_merge_identity():
    """Sharded serving at code_bits=4: ids and distances bitwise match
    the single-device nibble engines for all three index kinds
    (each shard unpacks its slice once at body entry).  Subprocess: the
    in-process suite must keep seeing one device (conftest)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_4BIT_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_4BIT_OK" in proc.stdout


# ----------------------------------------------------------- api layer ----

def test_config_code_bits_validation():
    from repro.api import ConfigError, ICQConfig

    with pytest.raises(ConfigError, match="index.code_bits=4"):
        ICQConfig.from_dict({"schema_version": 1,
                             "index": {"code_bits": 4},
                             "train": {"codebook_size": 64}})
    with pytest.raises(ConfigError, match="not one of"):
        ICQConfig.from_dict({"schema_version": 1,
                             "index": {"code_bits": 5}})
    # old configs without the field keep serving 8-bit
    cfg = ICQConfig.from_dict({"schema_version": 1,
                               "index": {"kind": "flat"}})
    assert cfg.index.code_bits == 8
    ok = ICQConfig.from_dict({"schema_version": 1,
                              "index": {"code_bits": 4},
                              "train": {"codebook_size": 16}})
    assert ok.index.code_bits == 4


@pytest.mark.parametrize("kind", ["flat", "two-step", "ivf"])
def test_artifacts_4bit_bitwise_round_trip(tmp_path, kind):
    """fit→save→load→search at code_bits=4: the stored codes stay
    nibble-packed uint8 and the reloaded engine serves bitwise-identical
    ids and distances for every index kind."""
    from repro.api import (Artifacts, ICQConfig, IndexConfig, ServeConfig,
                           TrainConfig, build_ann_engine, load_ann_engine)
    from repro.data.synthetic import make_synthetic_index

    key = jax.random.PRNGKey(0)
    n, K = 1500, 8
    codes, C, structure = make_synthetic_index(key, n, d=16, K=K, m=16,
                                               num_fast=2)
    emb_db = cb.decode(C, codes)
    engine = build_ann_engine(codes, C, structure, topk=20, backend="jnp",
                              index=kind, emb_db=emb_db, n_lists=16,
                              n_probe=4, lut_dtype="int8", code_bits=4,
                              key=jax.random.PRNGKey(1))
    assert np.asarray(engine.index.codes).shape[-1] == (K + 1) // 2
    q = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
    r0 = engine(q)
    cfg = ICQConfig(train=TrainConfig(codebook_size=16),
                    index=IndexConfig(kind=kind, n_lists=16, n_probe=4,
                                      code_bits=4),
                    serve=ServeConfig(topk=20, backend="jnp",
                                      lut_dtype="int8"))
    path = str(tmp_path / f"art4_{kind}")
    Artifacts(config=cfg, index=engine.index).save(path)
    loaded = load_ann_engine(path)
    stored = np.asarray(loaded.index.codes)
    assert stored.dtype == np.uint8 and stored.shape[-1] == (K + 1) // 2
    r1 = loaded(q)
    assert np.array_equal(np.asarray(r0.indices), np.asarray(r1.indices))
    assert np.array_equal(np.asarray(r0.distances),
                          np.asarray(r1.distances))


def test_artifacts_code_bits_override_rejected(tmp_path):
    """code_bits is a storage property, not a serving knob: loading a
    4-bit artifact with index.code_bits=8 overridden must fail (the
    bytes on disk are nibble-packed)."""
    from repro.api import (ArtifactError, Artifacts, ICQConfig,
                          IndexConfig, ServeConfig, TrainConfig,
                          build_ann_engine, load_ann_engine)
    from repro.data.synthetic import make_synthetic_index

    key = jax.random.PRNGKey(0)
    codes, C, structure = make_synthetic_index(key, 600, d=16, K=4, m=16,
                                               num_fast=2)
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp",
                              code_bits=4)
    cfg = ICQConfig(train=TrainConfig(codebook_size=16),
                    index=IndexConfig(kind="two-step", code_bits=4),
                    serve=ServeConfig(topk=10, backend="jnp"))
    path = str(tmp_path / "art4_override")
    Artifacts(config=cfg, index=engine.index).save(path)
    with pytest.raises(ArtifactError, match="code_bits"):
        load_ann_engine(path, overrides={"index.code_bits": 8})


# ------------------------------------------------------ trainer/encoder ----

def test_encode_database_4bit(key):
    """The tiled encoder emits nibble-packed codes under code_bits=4 —
    exactly pack_nibbles of its 8-bit output — and rejects geometries
    the nibble format cannot address."""
    from repro.trainer import encode_database

    K, m, d = 5, 16, 8
    C = jax.random.normal(key, (K, m, d)) * 0.3
    emb = jax.random.normal(jax.random.fold_in(key, 1), (333, d))
    codes8 = encode_database(emb, C, icm_iters=2)
    codes4 = encode_database(emb, C, icm_iters=2, code_bits=4)
    assert codes4.shape == (333, (K + 1) // 2) and codes4.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(codes4),
                                  np.asarray(pack_nibbles(codes8, K)))
    C_wide = jax.random.normal(key, (K, 32, d))
    with pytest.raises(ValueError, match="16"):
        encode_database(emb, C_wide, code_bits=4)
    with pytest.raises(ValueError, match="pack"):
        encode_database(emb, C, code_bits=4, pack=False)


def test_trainer_m16_end_to_end(key):
    """A K=8, m=16 quantizer fits, encodes within nibble range, and the
    4-bit engine over its packed codes matches the 8-bit engine
    bitwise — the full train→encode→search path at code_bits=4."""
    from repro.configs.base import ICQConfig as CoreICQConfig
    from repro.core import fit
    from repro.data import make_table1_dataset

    xtr, ytr, xte, _ = make_table1_dataset("dataset2")
    xtr, ytr, xte = xtr[:600], ytr[:600], xte[:16]
    cfg = CoreICQConfig(d=16, num_codebooks=8, codebook_size=16,
                        num_fast=2)
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq",
                epochs=2, batch_size=128)
    assert model.C.shape == (8, 16, 16)
    assert int(jnp.max(model.codes)) < 16
    emb_q = model.embed(xte)
    packed = pack_nibbles(model.codes, 8)
    r8 = two_step_search(emb_q, model.codes, model.C, model.structure,
                         15, backend="jnp", lut_dtype="int8")
    r4 = two_step_search(emb_q, packed, model.C, model.structure, 15,
                         backend="jnp", lut_dtype="int8", code_bits=4)
    np.testing.assert_array_equal(np.asarray(r4.indices),
                                  np.asarray(r8.indices))
    np.testing.assert_array_equal(np.asarray(r4.distances),
                                  np.asarray(r8.distances))
