"""Filtered search (per-row boolean metadata predicate): parity vs the
filtered brute-force oracle, exclusion invariants across all three index
kinds, the documented jnp-only contract on the pallas backend, the
empty/all-pass edge predicates, the AnnEngine/Searcher front door, and
sharded == single-device identity (subprocess under 4 forced host
devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import eval as ev
from repro.core import codebooks as cb
from repro.core import icq as icq_mod
from repro.index import FlatADC, IVFTwoStep, TwoStep


def _problem(key, n=300, nq=6, K=4, m=16, kf=2, d=8, sigma=50.0):
    """sigma is generous by default so eq. 2 refines everything — the
    filtered/unfiltered comparisons then exercise the predicate logic,
    not threshold noise."""
    C = jax.random.normal(key, (K, m, d)) * 0.5
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool),
                              fast_mask=jnp.zeros((K,), bool)
                              .at[:kf].set(True),
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


def _kinds(q, codes, C, st, key, topk=20, **kw):
    emb = cb.decode(C, codes)
    return [
        ("flat", FlatADC.build(codes, C, topk=topk, backend="jnp", **kw)),
        ("two_step", TwoStep.build(codes, C, st, topk=topk, backend="jnp",
                                   **kw)),
        ("ivf", IVFTwoStep.build(codes, C, st, emb_db=emb,
                                 key=jax.random.fold_in(key, 3),
                                 n_lists=8, n_probe=8, topk=topk,
                                 backend="jnp", **kw)),
    ]


# ------------------------------------------------- oracle parity ----

def test_flatadc_filtered_matches_exact_oracle(key):
    """With a single codebook the ADC distance IS the exact L2 distance
    to the decoded point, so filtered FlatADC must reproduce the
    filtered brute-force oracle (``repro.eval.ground_truth``) id for
    id."""
    n, d = 200, 6
    C = jax.random.normal(key, (1, 256, d))
    # distinct codes -> distinct decoded points (no distance ties to
    # make the id comparison ambiguous)
    codes = jax.random.permutation(
        jax.random.fold_in(key, 1), 256)[:n].reshape(n, 1).astype(jnp.uint8)
    db = cb.decode(C, codes)
    q = jax.random.normal(jax.random.fold_in(key, 2), (5, d))
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.4, (n,)))
    idx = FlatADC.build(codes, C, topk=10, backend="jnp")
    res = idx.search(q, filter=jnp.asarray(pred))
    gt_ids, _ = ev.ground_truth(db, q, 10, filter=pred)
    np.testing.assert_array_equal(np.asarray(res.indices, np.int64),
                                  gt_ids)


def test_filtered_equals_physically_compacted_db(key):
    """Filtering with a predicate == physically deleting the excluded
    rows (ids mapped back), for the flat engines: excluded rows must
    influence nothing — not the eq. 2 bootstrap, not the threshold, not
    the ranking."""
    q, codes, C, st = _problem(key, sigma=2.0)   # selective eq. 2
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.5, (codes.shape[0],)))
    keep = np.nonzero(pred)[0]
    assert len(keep) > 25
    for name, full_idx, sub_idx in [
        ("flat",
         FlatADC.build(codes, C, topk=15, backend="jnp"),
         FlatADC.build(codes[keep], C, topk=15, backend="jnp")),
        ("two_step",
         TwoStep.build(codes, C, st, topk=15, backend="jnp"),
         TwoStep.build(codes[keep], C, st, topk=15, backend="jnp")),
    ]:
        r_f = full_idx.search(q, filter=jnp.asarray(pred))
        r_c = sub_idx.search(q)
        np.testing.assert_array_equal(
            np.asarray(r_f.indices), keep[np.asarray(r_c.indices)],
            err_msg=name)
        np.testing.assert_allclose(np.asarray(r_f.distances),
                                   np.asarray(r_c.distances), rtol=1e-5,
                                   err_msg=name)


def test_ivf_filtered_matches_flat_filtered_full_probe(key):
    """IVF probing every list sees the same candidate set as the flat
    two-step engine, so their filtered rankings must agree."""
    q, codes, C, st = _problem(key)
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.5, (codes.shape[0],)))
    flat = TwoStep.build(codes, C, st, topk=15, backend="jnp")
    ivf = IVFTwoStep.build(codes, C, st, emb_db=cb.decode(C, codes),
                           key=jax.random.fold_in(key, 3), n_lists=8,
                           n_probe=8, topk=15, backend="jnp")
    r_flat = flat.search(q, filter=jnp.asarray(pred))
    r_ivf = ivf.search(q, filter=jnp.asarray(pred))
    np.testing.assert_array_equal(np.asarray(r_flat.indices),
                                  np.asarray(r_ivf.indices))


def test_filtered_recall_vs_filtered_oracle(key):
    """Tie-aware recall of every filtered engine against the filtered
    exact oracle over the decoded database — the scenario-matrix metric
    the sweep reports.  All engines refine every candidate here
    (generous sigma, full probe), so recall is limited only by the
    cross-codebook ADC approximation; the floor is deliberately
    conservative."""
    q, codes, C, st = _problem(key)
    db = cb.decode(C, codes)
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.5, (codes.shape[0],)))
    for name, idx in _kinds(q, codes, C, st, key):
        res = idx.search(q, filter=jnp.asarray(pred))
        rec = ev.tie_aware_recall_at_k(np.asarray(res.indices), q, db,
                                       10, filter=pred, rtol=0.35)
        assert rec >= 0.8, (name, rec)


# --------------------------------------------------- invariants ----

def test_filtered_ids_respect_predicate(key):
    q, codes, C, st = _problem(key)
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.3, (codes.shape[0],)))
    for name, idx in _kinds(q, codes, C, st, key):
        ids = np.asarray(idx.search(q, filter=jnp.asarray(pred)).indices)
        ok = (ids == -1) | pred[np.clip(ids, 0, None)]
        assert ok.all(), name


def test_all_pass_filter_is_bitwise_unfiltered(key):
    q, codes, C, st = _problem(key)
    allpass = jnp.ones((codes.shape[0],), bool)
    for name, idx in _kinds(q, codes, C, st, key):
        r0 = idx.search(q)
        r1 = idx.search(q, filter=allpass)
        np.testing.assert_array_equal(np.asarray(r0.indices),
                                      np.asarray(r1.indices), err_msg=name)
        np.testing.assert_array_equal(np.asarray(r0.distances),
                                      np.asarray(r1.distances),
                                      err_msg=name)


def test_empty_filter_returns_all_padding(key):
    q, codes, C, st = _problem(key)
    none = jnp.zeros((codes.shape[0],), bool)
    for name, idx in _kinds(q, codes, C, st, key):
        res = idx.search(q, filter=none)
        assert np.all(np.asarray(res.indices) == -1), name
        assert np.all(np.isinf(np.asarray(res.distances))), name


def test_fewer_passing_rows_than_topk_pads(key):
    q, codes, C, st = _problem(key)
    pred = np.zeros((codes.shape[0],), bool)
    pred[[3, 71, 208]] = True
    for name, idx in _kinds(q, codes, C, st, key):
        ids = np.asarray(idx.search(q, filter=jnp.asarray(pred)).indices)
        assert ids.shape[1] == 20, name
        for row in ids:
            valid = row[row >= 0]
            assert set(valid) == {3, 71, 208}, name
            assert np.all(row[3:] == -1), name


def test_filter_rejects_pallas_and_bad_shapes(key):
    q, codes, C, st = _problem(key, n=64)
    pred = jnp.ones((64,), bool)
    for idx in (FlatADC.build(codes, C, topk=5, backend="pallas",
                              interpret=True),
                TwoStep.build(codes, C, st, topk=5, backend="pallas",
                              interpret=True)):
        with pytest.raises(ValueError, match="filtered search requires"):
            idx.search(q, filter=pred)
    flat = FlatADC.build(codes, C, topk=5, backend="jnp")
    with pytest.raises(ValueError, match="filter"):
        flat.search(q, filter=jnp.ones((63,), bool))    # wrong length
    with pytest.raises(ValueError, match="filter"):
        flat.search(q, filter=jnp.ones((8, 8), bool))   # wrong rank


# ----------------------------------------------------- front door ----

def test_ann_engine_filtered_search(key):
    from repro.api import build_ann_engine
    q, codes, C, st = _problem(key)
    engine = build_ann_engine(codes, C, st, topk=10, backend="jnp")
    pred = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.3, (codes.shape[0],)))
    r = engine.search(q, filter=pred)
    ids = np.asarray(r.indices)
    assert ((ids == -1) | pred[np.clip(ids, 0, None)]).all()
    # crude-only degraded level honors the filter too
    from repro.resilience import SearchBudget
    r2 = engine.search(q, budget=SearchBudget(allow_refine=False),
                       filter=pred)
    ids2 = np.asarray(r2.indices)
    assert ((ids2 == -1) | pred[np.clip(ids2, 0, None)]).all()


def test_ann_engine_filter_on_pallas_raises_without_blacklisting(key):
    """A user error (filter + pallas) must raise immediately and must
    NOT trip the failover machinery: the pallas backend stays usable
    for unfiltered queries afterwards."""
    from repro.api import build_ann_engine
    q, codes, C, st = _problem(key, n=64)
    engine = build_ann_engine(codes, C, st, topk=5, backend="pallas")
    with pytest.raises(ValueError, match="filtered search requires"):
        engine.search(q, filter=np.ones(64, bool))
    r = engine.search(q)                       # still on pallas, no fallback
    assert r.indices.shape == (q.shape[0], 5)
    assert engine.stats.get("failovers", 0) == 0


# -------------------------------------------------------- sharded ----

_SHARDED_FILTER_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.index import FlatADC, IVFTwoStep, TwoStep

    key = jax.random.PRNGKey(0)
    n, nq, K, m, d, kf = 1237, 9, 4, 16, 8, 2
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool),
                              fast_mask=jnp.zeros((K,), bool)
                              .at[:kf].set(True),
                              sigma=jnp.asarray(50.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    pred = np.asarray(jax.random.bernoulli(jax.random.fold_in(key, 4),
                                           0.4, (n,)))
    mesh = jax.make_mesh((4,), ("data",))

    def check(idx, tag):
        r1 = idx.search(q, filter=jnp.asarray(pred))
        r4 = idx.shard(mesh).search(q, filter=jnp.asarray(pred))
        np.testing.assert_array_equal(np.asarray(r1.indices),
                                      np.asarray(r4.indices), err_msg=tag)
        d1, d4 = np.asarray(r1.distances), np.asarray(r4.distances)
        fin = np.isfinite(d1)
        assert (fin == np.isfinite(d4)).all(), tag
        np.testing.assert_allclose(d1[fin], d4[fin], atol=1e-5,
                                   err_msg=tag)
        # unfiltered path through the same sharded wrapper is untouched
        s1, s4 = idx.search(q), idx.shard(mesh).search(q)
        np.testing.assert_array_equal(np.asarray(s1.indices),
                                      np.asarray(s4.indices), err_msg=tag)

    check(FlatADC.build(codes, C, topk=17, backend="jnp"), "flat")
    check(TwoStep.build(codes, C, st, topk=17, backend="jnp"), "two-step")
    check(IVFTwoStep.build(codes, C, st, emb_db=cb.decode(C, codes),
                           key=jax.random.fold_in(key, 3), n_lists=16,
                           n_probe=5, topk=17, backend="jnp"),
          "ivf")
    print("SHARDED_FILTER_OK")
""")


def test_sharded_filtered_matches_single_device():
    """Filtered sharded search == filtered single-device search on a
    forced 4-device host platform (row-sharded predicate layout for
    flat/two-step, replicated predicate for IVF).  Subprocess: this
    suite must keep seeing one device (conftest)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_FILTER_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_FILTER_OK" in proc.stdout
