"""Validation of the trip-count-aware HLO cost analyzer (launch.hlo_cost)
against XLA's own counts on loop-free programs and against
scanned-vs-unrolled equivalence — the basis of the roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import make_mesh_auto, shard_map_compat
from repro.launch.hlo_cost import analyze_hlo, xla_cost_analysis


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_loop_free_matches_xla():
    def f(x, w):
        return jnp.tanh(x @ w) @ w.T

    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(f, x, w)
    mine = analyze_hlo(c.as_text())
    xla = xla_cost_analysis(c)
    assert mine["flops"] == pytest.approx(xla["flops"], rel=1e-6)
    assert mine["flops"] == pytest.approx(2 * 2 * 256 * 512 * 512, rel=1e-6)


def test_scan_equals_unrolled():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def g_scan(x, ws):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, ws)[0]

    def g_unroll(x, ws):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ ws[i])
        return h

    ms = analyze_hlo(_compile(g_scan, x, ws).as_text())
    mu = analyze_hlo(_compile(g_unroll, x, ws).as_text())
    assert ms["flops"] == pytest.approx(mu["flops"], rel=1e-6)
    assert ms["flops"] == pytest.approx(8 * 2 * 128 * 256 * 256, rel=1e-6)
    # bytes: scan adds loop-carry traffic; must agree within 2x and both
    # scale with the trip count (XLA's builtin reports ~1/8 of this)
    assert 0.5 < ms["bytes"] / mu["bytes"] < 2.0


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def outer(h, _):
            def inner(g, _):
                return jnp.tanh(g @ g), None
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    m = analyze_hlo(_compile(f, x).as_text())
    assert m["flops"] == pytest.approx(15 * 2 * 64 * 64 * 64, rel=1e-6)


def test_collectives_counted_with_multiplier():
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh_auto((1,), ("d",))

    def h_fn(x):
        def body(c, _):
            s = jax.lax.psum(c, "d")
            return c + 0 * s, s
        out, ss = jax.lax.scan(body, x, None, length=5)
        return out, ss

    sm = shard_map_compat(h_fn, mesh, P("d"),
                          (P("d"), P(None, "d")))
    c = jax.jit(sm).lower(
        jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
    m = analyze_hlo(c.as_text())
    assert m["collective_bytes"] == pytest.approx(5 * 16 * 64 * 4, rel=1e-6)
    assert "all-reduce" in m["collectives_by_op"]


def test_dryrun_exec_flops_vs_hlo_on_real_cell():
    """End-to-end audit: the measured (trip-count-corrected) HLO flops of
    a real train cell must land within 35% of the analytic 8/6*6ND
    estimate (slack: attention flops, CE head, z-loss, norms)."""
    import dataclasses
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.dryrun import exec_flops
    from repro.launch.steps import lower_cell, plan_cell
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    cfg = dataclasses.replace(get_config("tinyllama-1.1b"), num_layers=2,
                              microbatch_size=2)
    shape = ShapeSpec(name="t", seq_len=512, global_batch=2, kind="train")
    plan = plan_cell(cfg, shape, mesh)
    compiled = lower_cell(plan).compile()
    m = analyze_hlo(compiled.as_text())
    ana = exec_flops(plan.cfg, shape)
    assert 0.65 < m["flops"] / ana < 1.35
