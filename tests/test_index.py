"""Unified index layer (DESIGN.md §7): batched IVF vs the per-query
oracle, the fused-kernel IVF variant, refine_cap compaction, the Index
protocol classes, exact_search chunking, and sharded-serving parity
(subprocess under XLA_FLAGS=--xla_force_host_platform_device_count=4 —
the in-process suite must keep seeing 1 device, see conftest)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebooks as cb_mod
from repro.core import encode as enc_mod
from repro.core import icq as icq_mod
from repro.index import (FlatADC, Index, IVFTwoStep, TwoStep, adc_search,
                         build_ivf, exact_search, ivf_list_codes,
                         ivf_two_step_search, make_index, two_step_search)
from repro.index.ivf import IVFIndex
from repro.kernels.ref import ivf_two_step_search_looped


def _problem(key, n, nq, K=4, m=16, kf=2, d=8, sigma=1.0):
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    from repro.core import codebooks as cb
    emb = cb.decode(C, codes)
    return q, codes, C, st, emb


# -------------------------------------------------------- batched IVF ----

@pytest.mark.parametrize("n,nq,n_lists,n_probe", [
    (1237, 9, 16, 4),        # non-divisible everything
    (530, 7, 13, 1),         # n_probe = 1
    (530, 7, 13, 13),        # n_probe = n_lists
])
def test_batched_ivf_matches_looped_oracle(key, n, nq, n_lists, n_probe):
    """Batched candidate-gather engine == the per-query lax.map oracle:
    exact ids, 1e-4 distances, identical ops accounting — with and
    without the in-list codes slab."""
    q, codes, C, st, emb = _problem(jax.random.fold_in(key, n), n, nq)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, n_lists)
    topk = 17
    r_loop = ivf_two_step_search_looped(q, codes, C, st, ivf, topk, n_probe)
    slab = ivf_list_codes(ivf, codes)
    for lc in (None, slab):
        r_b = ivf_two_step_search(q, codes, C, st, ivf, topk, n_probe,
                                  backend="jnp", list_codes=lc)
        np.testing.assert_array_equal(np.asarray(r_b.indices),
                                      np.asarray(r_loop.indices))
        np.testing.assert_allclose(np.asarray(r_b.distances),
                                   np.asarray(r_loop.distances), atol=1e-4)
        assert float(r_b.pass_rate) == pytest.approx(
            float(r_loop.pass_rate), abs=1e-6)
        assert float(r_b.avg_ops) == pytest.approx(
            float(r_loop.avg_ops), abs=1e-6)


def test_ivf_pallas_matches_jnp(key):
    q, codes, C, st, emb = _problem(key, 911, 6, sigma=2.0)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, 16)
    r_j = ivf_two_step_search(q, codes, C, st, ivf, 17, 4, backend="jnp")
    r_p = ivf_two_step_search(q, codes, C, st, ivf, 17, 4,
                              backend="pallas", interpret=True,
                              block_q=4, block_n=96)
    np.testing.assert_array_equal(np.asarray(r_p.indices),
                                  np.asarray(r_j.indices))
    np.testing.assert_allclose(np.asarray(r_p.distances),
                               np.asarray(r_j.distances), atol=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-5)


def test_ivf_handles_empty_lists(key):
    """Hand-built IVF with empty + short lists: every returned finite
    hit is a real candidate of a probed list."""
    q, codes, C, st, emb = _problem(key, 60, 5)
    cent = jax.random.normal(jax.random.fold_in(key, 9), (6, 8))
    lists = jnp.full((6, 30), -1, jnp.int32)
    lists = lists.at[0, :30].set(jnp.arange(30))
    lists = lists.at[2, :20].set(jnp.arange(30, 50))
    lists = lists.at[5, :10].set(jnp.arange(50, 60))
    # rows 1, 3, 4 stay empty
    ivf = IVFIndex(centroids=cent, lists=lists,
                   list_lens=jnp.asarray([30, 0, 20, 0, 0, 10]),
                   imbalance=3.0)
    r = ivf_two_step_search(q, codes, C, st, ivf, 8, 3, backend="jnp")
    finite = np.isfinite(np.asarray(r.distances))
    ids = np.asarray(r.indices)
    assert (ids[finite] >= 0).all() and (ids[finite] < 60).all()
    # probing everything == exhaustive two-step over all 60 points
    r_all = ivf_two_step_search(q, codes, C, st, ivf, 8, 6, backend="jnp")
    r_flat = two_step_search(q, codes, C, st, 8, backend="jnp")
    finite = np.isfinite(np.asarray(r_all.distances))
    np.testing.assert_array_equal(np.asarray(r_all.indices)[finite],
                                  np.asarray(r_flat.indices)[finite])


def test_ivf_all_empty_buckets_edge():
    """build_ivf survives k-means collapse (n_lists >> n)."""
    key = jax.random.PRNGKey(0)
    emb = jax.random.normal(key, (5, 8))
    ivf = build_ivf(key, emb, n_lists=12)
    assert ivf.lists.shape[0] == 12 and ivf.lists.shape[1] >= 1
    # each db id appears exactly once across the lists
    ids = np.asarray(ivf.lists).ravel()
    assert sorted(ids[ids >= 0].tolist()) == list(range(5))
    with pytest.raises(ValueError):
        build_ivf(key, emb[:0], n_lists=4)
    with pytest.raises(ValueError):
        build_ivf(key, emb, n_lists=0)


def test_ivf_refine_cap(key):
    """cap >= survivor count == dense ranking; a small cap still returns
    sorted full distances over genuine candidates."""
    q, codes, C, st, emb = _problem(key, 700, 6, sigma=3.0)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, 8)
    r_dense = ivf_two_step_search(q, codes, C, st, ivf, 11, 4,
                                  backend="jnp")
    r_cap = ivf_two_step_search(q, codes, C, st, ivf, 11, 4, backend="jnp",
                                refine_cap=700 * 4)
    np.testing.assert_array_equal(np.asarray(r_cap.indices),
                                  np.asarray(r_dense.indices))
    # refine_cap smaller than the survivor count: quality dial engages
    r_small = ivf_two_step_search(q, codes, C, st, ivf, 11, 4,
                                  backend="jnp", refine_cap=12)
    d = np.asarray(r_small.distances)
    assert (np.diff(d, axis=1)[np.isfinite(d[:, 1:])] >= 0).all()
    assert float(r_small.pass_rate) == pytest.approx(
        float(r_dense.pass_rate), abs=1e-6)   # accounting is cap-blind
    # pallas rejects the cap explicitly
    with pytest.raises(ValueError):
        ivf_two_step_search(q, codes, C, st, ivf, 11, 4, backend="pallas",
                            refine_cap=12)


def test_two_step_refine_cap_dispatch(key):
    """The compact engine is an option of the unified dispatch."""
    q, codes, C, st, emb = _problem(key, 400, 7)
    r_dense = two_step_search(q, codes, C, st, 9, backend="jnp")
    r_cap = two_step_search(q, codes, C, st, 9, backend="jnp",
                            refine_cap=400)
    np.testing.assert_array_equal(np.asarray(r_cap.indices),
                                  np.asarray(r_dense.indices))
    with pytest.raises(ValueError):
        two_step_search(q, codes, C, st, 9, backend="pallas",
                        refine_cap=10)


# ---------------------------------------------------------- protocol ----

def test_index_protocol_classes(key):
    q, codes, C, st, emb = _problem(key, 300, 5)
    flat = FlatADC.build(codes, C, topk=9, backend="jnp")
    two = TwoStep.build(codes, C, st, topk=9, backend="jnp")
    ivf = IVFTwoStep.build(codes, C, st, emb_db=emb, key=key, n_lists=8,
                           n_probe=8, topk=9, backend="jnp")
    for idx in (flat, two, ivf):
        assert isinstance(idx, Index)
        r = idx.search(q)
        assert r.indices.shape == (5, 9)
    np.testing.assert_array_equal(
        np.asarray(flat.search(q).indices),
        np.asarray(adc_search(q, codes, C, 9, backend="jnp").indices))
    np.testing.assert_array_equal(
        np.asarray(two.search(q).indices),
        np.asarray(two_step_search(q, codes, C, st, 9,
                                   backend="jnp").indices))
    # probing every list with pruning disabled (sigma -> inf) == the
    # exhaustive ranking: candidate *order* differs (slab vs db), so
    # with a finite margin the eq. 2 bootstrap may resolve crude *ties*
    # differently — without pruning the rankings must coincide exactly
    st_inf = icq_mod.ICQStructure(xi=st.xi, fast_mask=st.fast_mask,
                                  sigma=jnp.asarray(1e30))
    ivf_inf = IVFTwoStep(codes=codes, C=C, structure=st_inf, ivf=ivf.ivf,
                         n_probe=8, topk=9, backend="jnp",
                         list_codes=ivf.list_codes)
    r_ivf = ivf_inf.search(q)
    r_two = two_step_search(q, codes, C, st_inf, 9, backend="jnp")
    np.testing.assert_array_equal(np.asarray(r_ivf.indices),
                                  np.asarray(r_two.indices))
    # per-call topk override
    assert ivf.search(q, topk=4).indices.shape == (5, 4)
    # factory
    got = make_index("two-step", codes, C, st, topk=9, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got.search(q).indices),
                                  np.asarray(two.search(q).indices))
    with pytest.raises(ValueError):
        make_index("nope", codes, C, st)


def test_exact_search_query_chunk_invariant(key):
    x = jax.random.normal(key, (400, 8))
    q = jax.random.normal(jax.random.fold_in(key, 1), (23, 8))
    i_full, d_full = exact_search(q, x, 10)
    i_chunk, d_chunk = exact_search(q, x, 10, query_chunk=7)
    np.testing.assert_array_equal(np.asarray(i_full), np.asarray(i_chunk))
    np.testing.assert_allclose(np.asarray(d_full), np.asarray(d_chunk),
                               rtol=1e-6)


# ----------------------------------------------------------- sharding ----

_SHARDED_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.index import FlatADC, IVFTwoStep, TwoStep

    key = jax.random.PRNGKey(0)
    n, nq, K, m, d, kf = 1237, 9, 4, 16, 8, 2
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    emb = cb.decode(C, codes)
    mesh = jax.make_mesh((4,), ("data",))

    def check(idx, tag):
        r1, r4 = idx.search(q), idx.shard(mesh).search(q)
        np.testing.assert_array_equal(np.asarray(r1.indices),
                                      np.asarray(r4.indices), err_msg=tag)
        np.testing.assert_allclose(np.asarray(r1.distances),
                                   np.asarray(r4.distances), atol=1e-5,
                                   err_msg=tag)
        assert float(r1.pass_rate) == float(r4.pass_rate), tag
        assert float(r1.avg_ops) == float(r4.avg_ops), tag

    check(FlatADC.build(codes, C, topk=17, backend="jnp"), "flat")
    check(TwoStep.build(codes, C, st, topk=17, backend="jnp"), "two-step")
    for n_lists, n_probe, cap in [(16, 4, None), (16, 1, None),
                                  (16, 16, None), (13, 5, None),
                                  (16, 4, 20)]:
        idx = IVFTwoStep.build(codes, C, st, emb_db=emb,
                               key=jax.random.fold_in(key, 3),
                               n_lists=n_lists, n_probe=n_probe, topk=17,
                               backend="jnp", refine_cap=cap)
        check(idx, f"ivf-{n_lists}-{n_probe}-{cap}")
    print("SHARDED_PARITY_OK")
""")


def test_sharded_merge_matches_single_device():
    """Per-shard top-k + global merge == single-device results (ids
    exact, distances to reassociation tolerance) on a forced 4-device
    host platform.  Runs in a subprocess: this suite must keep seeing a
    single device (conftest)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_PARITY_OK" in proc.stdout


# ------------------------------------------------- incremental builds ----

def _icq_problem(key, n, d=16, K=4, m=16):
    """A *real* additive-codebook problem (projected ICQ codebooks) so
    add()'s ICM encoding is exercised with genuine interactions."""
    emb = jax.random.normal(key, (n, d)) * jnp.linspace(0.3, 2.0, d)
    C = cb_mod.init_residual(key, emb, K, m, iters=5)
    xi = jnp.asarray([1] * (d // 3) + [0] * (d - d // 3), bool)
    fast = jnp.zeros((K,), bool).at[:2].set(True)
    C = icq_mod.project_codebooks(C, xi, fast)
    st = icq_mod.ICQStructure(xi=xi, fast_mask=fast, sigma=jnp.asarray(1.0))
    codes = enc_mod.pack_codes(enc_mod.icm_encode(emb, C, 3, backend="jnp"),
                               m)
    return emb, C, st, codes


def test_add_flat_and_two_step_identical_to_rebuild(key):
    """Index.add == from-scratch build on the concatenated dataset:
    encoding is per-point, so appended rows carry the exact codes a
    full rebuild would assign (ids and distances identical)."""
    emb, C, st, codes_all = _icq_problem(key, 900)
    e1, e2 = emb[:700], emb[700:]
    codes1 = enc_mod.pack_codes(enc_mod.icm_encode(e1, C, 3,
                                                   backend="jnp"), 16)
    q = jax.random.normal(jax.random.fold_in(key, 9), (7, 16))
    for build in (lambda c: FlatADC.build(c, C, topk=9, backend="jnp"),
                  lambda c: TwoStep.build(c, C, st, topk=9, backend="jnp")):
        grown = build(codes1).add(e2, icm_iters=3)
        ref = build(codes_all)
        assert grown.codes.dtype == ref.codes.dtype
        np.testing.assert_array_equal(np.asarray(grown.codes),
                                      np.asarray(ref.codes))
        rg, rr = grown.search(q), ref.search(q)
        np.testing.assert_array_equal(np.asarray(rg.indices),
                                      np.asarray(rr.indices))
        np.testing.assert_array_equal(np.asarray(rg.distances),
                                      np.asarray(rr.distances))


def test_add_ivf_identical_to_rebuild_same_centroids(key):
    """IVF add keeps the coarse centroids fixed; the reference build is
    ivf_assign over the concatenated embeddings with those centroids —
    lists, slab, and search results must all match."""
    import dataclasses as dc
    from repro.index import ivf_assign, ivf_list_codes
    emb, C, st, codes_all = _icq_problem(key, 900)
    e1, e2 = emb[:700], emb[700:]
    codes1 = enc_mod.pack_codes(enc_mod.icm_encode(e1, C, 3,
                                                   backend="jnp"), 16)
    q = jax.random.normal(jax.random.fold_in(key, 9), (7, 16))
    idx = IVFTwoStep.build(codes1, C, st, emb_db=e1, key=key, n_lists=8,
                           n_probe=4, topk=9, backend="jnp")
    grown = idx.add(e2, icm_iters=3)
    ivf_ref = ivf_assign(idx.ivf.centroids, emb)
    ref = IVFTwoStep(codes=codes_all, C=C, structure=st, ivf=ivf_ref,
                     n_probe=4, topk=9, backend="jnp",
                     list_codes=ivf_list_codes(ivf_ref, codes_all))
    np.testing.assert_array_equal(np.asarray(grown.ivf.lists),
                                  np.asarray(ref.ivf.lists))
    np.testing.assert_array_equal(np.asarray(grown.list_codes),
                                  np.asarray(ref.list_codes))
    rg, rr = grown.search(q), ref.search(q)
    np.testing.assert_array_equal(np.asarray(rg.indices),
                                  np.asarray(rr.indices))
    np.testing.assert_array_equal(np.asarray(rg.distances),
                                  np.asarray(rr.distances))


def test_add_grows_max_len_when_lists_overflow(key):
    """Appending enough rows to one cell must grow the padded slab."""
    emb, C, st, _ = _icq_problem(key, 300)
    e1 = emb[:200]
    codes1 = enc_mod.pack_codes(enc_mod.icm_encode(e1, C, 3,
                                                   backend="jnp"), 16)
    idx = IVFTwoStep.build(codes1, C, st, emb_db=e1, key=key, n_lists=4,
                           n_probe=4, topk=5, backend="jnp")
    # 100 near-identical rows all route into one cell
    clones = jnp.broadcast_to(emb[0], (100, emb.shape[1])) \
        + 0.001 * jax.random.normal(key, (100, emb.shape[1]))
    grown = idx.add(clones)
    assert grown.ivf.lists.shape[1] > idx.ivf.lists.shape[1]
    assert grown.codes.shape[0] == 300
    r = grown.search(emb[:1])
    assert r.indices.shape == (1, 5)


def test_sharded_add_raises_with_guidance(key):
    q, codes, C, st, emb = _problem(key, 100, 2)
    idx = TwoStep.build(codes, C, st, topk=5, backend="jnp")
    from repro.distributed.sharding import make_mesh_auto
    sharded = idx.shard(make_mesh_auto((1,), ("data",)))
    with pytest.raises(NotImplementedError, match="source index"):
        sharded.add(emb[:3])


def test_ann_engine_add_reshards_and_serves(key):
    """AnnEngine keeps the unsharded source index: add() grows it and
    refreshes the jitted (or sharded) serving fn."""
    from repro.quant.serve_icq import build_ann_engine
    emb, C, st, _ = _icq_problem(key, 500)
    e1, e2 = emb[:400], emb[400:]
    codes1 = enc_mod.pack_codes(enc_mod.icm_encode(e1, C, 3,
                                                   backend="jnp"), 16)
    engine = build_ann_engine(codes1, C, st, topk=9, backend="jnp")
    q = jax.random.normal(jax.random.fold_in(key, 9), (4, 16))
    r0 = engine(q)
    assert engine.n == 400
    engine.add(e2)
    assert engine.n == 500
    r1 = engine(q)
    assert r1.indices.shape == r0.indices.shape
    # grown engine == engine built over everything at once
    codes_all = enc_mod.pack_codes(enc_mod.icm_encode(emb, C, 3,
                                                      backend="jnp"), 16)
    ref = build_ann_engine(codes_all, C, st, topk=9, backend="jnp")
    np.testing.assert_array_equal(np.asarray(r1.indices),
                                  np.asarray(ref(q).indices))
