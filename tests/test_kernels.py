"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,K,m", [(64, 2, 16), (512, 8, 64), (1000, 16, 256),
                                   (4096, 4, 256)])
def test_adc_sweep(key, n, K, m):
    codes = jax.random.randint(key, (n, K), 0, m)
    lut = jax.random.normal(jax.random.fold_in(key, 1), (K, m))
    got = ops.adc(codes, lut, interpret=True)
    want = ref.adc_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("n,K,m,kf", [(256, 8, 32, 2), (999, 16, 64, 4)])
def test_two_step_sweep(key, n, K, m, kf):
    codes = jax.random.randint(key, (n, K), 0, m)
    lut = jax.random.normal(jax.random.fold_in(key, 1), (K, m))
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    thr = 0.3
    crude, passed = ops.two_step(codes, lut, fast, thr, interpret=True)
    c0, p0 = ref.two_step_ref(codes, lut, fast, thr)
    np.testing.assert_allclose(np.asarray(crude), np.asarray(c0), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(passed), np.asarray(p0))


@pytest.mark.parametrize("n,nq,K,m,topk", [
    (300, 5, 4, 16, 8),        # non-divisible n and nq
    (1024, 8, 8, 32, 10),      # divisible
    (999, 3, 2, 64, 7),        # tiny K, odd n
])
def test_batched_crude_topk_sweep(key, n, nq, K, m, topk):
    codes = jax.random.randint(key, (n, K), 0, m)
    luts = jax.random.normal(jax.random.fold_in(key, 1), (nq, K, m))
    crude, vals, idx = ops.batched_crude_topk(
        codes, luts.reshape(nq, K * m), topk, block_q=2, block_n=128,
        interpret=True)
    crude0 = ref.batched_crude_ref(codes, luts)
    np.testing.assert_allclose(np.asarray(crude), np.asarray(crude0),
                               atol=1e-4)
    neg, idx0 = jax.lax.top_k(-crude0, topk)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx0))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(-neg), atol=1e-4)


@pytest.mark.parametrize("n,nq,K,m,topk,q_thr", [
    (300, 5, 4, 16, 8, 0.3),
    (999, 4, 8, 32, 10, 0.005),  # harsh threshold: fewer passers than topk
])
def test_batched_refine_topk_sweep(key, n, nq, K, m, topk, q_thr):
    """Fused eq. 2 test + slow sum + in-kernel top-k merge vs the
    monolithic oracle — exact index parity incl. the +inf pruned tail."""
    codes = jax.random.randint(key, (n, K), 0, m)
    luts = jax.random.normal(jax.random.fold_in(key, 1), (nq, K, m))
    crude0 = ref.batched_crude_ref(codes, luts)
    slow_luts = luts * 0.5
    thr = jnp.quantile(crude0, q_thr, axis=1)
    dist, idx = ops.batched_refine_topk(
        codes, slow_luts.reshape(nq, K * m), crude0, thr, topk,
        block_q=2, block_n=128, interpret=True)
    full0 = crude0 + ref.batched_crude_ref(codes, slow_luts)
    ranked0 = jnp.where(crude0 < thr[:, None], full0, jnp.inf)
    neg, idx0 = jax.lax.top_k(-ranked0, topk)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx0))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(-neg), atol=1e-4)


@pytest.mark.parametrize("n,d,m", [(128, 8, 4), (3000, 48, 96),
                                   (1024, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_sweep(key, n, d, m, dtype):
    x = jax.random.normal(key, (n, d), dtype)
    cent = jax.random.normal(jax.random.fold_in(key, 1), (m, d), dtype)
    ids, dist = ops.kmeans_assign(x, cent, interpret=True)
    ids0, dist0 = ref.kmeans_assign_ref(x, cent)
    # ties under low precision may flip ids; distances must agree
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist0),
                               rtol=tol, atol=tol)
    agree = np.mean(np.asarray(ids) == np.asarray(ids0))
    assert agree > (0.999 if dtype == jnp.float32 else 0.98)


@pytest.mark.parametrize("b,sq,sk,h,kvh,dh,causal", [
    (1, 64, 64, 4, 4, 32, True),
    (2, 128, 128, 8, 2, 64, True),
    (1, 64, 256, 4, 1, 32, False),     # cross-length, MQA
    (2, 256, 256, 8, 8, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(key, b, sq, sk, h, kvh, dh, causal, dtype):
    q = jax.random.normal(key, (b, sq, h, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kvh, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kvh, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, blk_q=64, blk_k=64,
                              interpret=True)
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = kk.transpose(0, 2, 1, 3).reshape(b * h, sk, dh)
    vf = vv.transpose(0, 2, 1, 3).reshape(b * h, sk, dh)
    want = ref.flash_attention_ref(qf, kf, vf, causal=causal)
    want = want.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_vs_model_chunked_attention(key):
    """The Pallas kernel and the GSPMD chunked path are interchangeable."""
    from repro.models.attention import chunked_attention
    q = jax.random.normal(key, (2, 256, 8, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 256, 2, 64))
    a = ops.flash_attention(q, k, v, causal=True, interpret=True)
    b = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
