"""Launch-layer integration on a small in-process mesh: plan/lower/compile
cells, microbatch geometry, and a real sharded train step that executes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import make_mesh_auto, shard_map_compat
from repro.configs.base import ShapeSpec
from repro.launch.steps import (batch_shardings, batch_struct,
                                build_train_step, num_microbatches,
                                plan_cell, lower_cell)


def _mesh11():
    return make_mesh_auto((1, 1), ("data", "model"))


def test_num_microbatches_geometry():
    cfg = dataclasses.replace(get_config("tinyllama-1.1b"),
                              microbatch_size=4)
    shape = ShapeSpec("t", seq_len=128, global_batch=256, kind="train")
    assert num_microbatches(cfg, shape, dp=16) == 4
    assert num_microbatches(cfg, shape, dp=32) == 2
    # always divides the global batch
    for dp in (1, 2, 4, 8, 16, 32):
        n = num_microbatches(cfg, shape, dp)
        assert shape.global_batch % n == 0


def test_batch_struct_shapes():
    cfg = get_config("internvl2-76b")
    shape = ShapeSpec("t", seq_len=4096, global_batch=8, kind="train")
    spec = batch_struct(cfg, shape, n_micro=2, train=True)
    assert spec["tokens"].shape == (2, 4, 4096 - 256)
    assert spec["patch_emb"].shape == (2, 4, 256, 3200)
    spec_s = batch_struct(cfg, shape, 1, train=False)
    assert spec_s["tokens"].shape == (8, 4096 - 256)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b"])
def test_smoke_cell_lower_compile_train(arch):
    """plan_cell -> lower -> compile on the 1x1 mesh with a reduced cfg."""
    cfg = dataclasses.replace(smoke_config(arch), microbatch_size=1)
    shape = ShapeSpec("t", seq_len=32, global_batch=2, kind="train")
    plan = plan_cell(cfg, shape, _mesh11())
    compiled = lower_cell(plan).compile()
    assert compiled.cost_analysis() is not None


def test_smoke_cell_decode(key):
    cfg = smoke_config("tinyllama-1.1b")
    shape = ShapeSpec("d", seq_len=64, global_batch=2, kind="decode")
    plan = plan_cell(cfg, shape, _mesh11())
    compiled = lower_cell(plan).compile()
    assert compiled is not None


def test_train_step_executes_and_descends():
    """Real execution: loss decreases over a few steps on memorizable data."""
    cfg = dataclasses.replace(smoke_config("tinyllama-1.1b"),
                              microbatch_size=1, ce_chunk=16)
    mesh = _mesh11()
    step_fn, model, opt, init_opt = build_train_step(cfg, n_micro=2,
                                                     mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    for _ in range(8):
        params, opt_state, mets = jit_step(params, opt_state, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_icq_grad_train_step_matches_plain_closely(key):
    """Compressed cross-pod combine with a pod axis of size 1 must agree
    with the uncompressed step up to int8 quantization noise."""
    from jax.sharding import PartitionSpec  # noqa: F401
    cfg = dataclasses.replace(smoke_config("tinyllama-1.1b"),
                              microbatch_size=1)
    mesh = make_mesh_auto((1, 1, 1), ("pod", "data", "model"))
    toks = jax.random.randint(key, (1, 2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    outs = {}
    for name, icq_grad in (("plain", False), ("icq", True)):
        step_fn, model, opt, init_opt = build_train_step(
            cfg, n_micro=1, multi_pod=True, icq_grad=icq_grad, mesh=mesh)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = init_opt(params)
        if icq_grad:
            step = jax.jit(shard_map_compat(
                step_fn, mesh,
                (PartitionSpec(),) * 3,
                (PartitionSpec(),) * 3))
        else:
            step = jax.jit(step_fn)
        p, o, m = step(params, opt_state, batch)
        outs[name] = (p, float(m["loss"]))
    assert outs["plain"][1] == pytest.approx(outs["icq"][1], rel=1e-5)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         outs["plain"][0], outs["icq"][0])
    assert max(jax.tree.leaves(diffs)) < 5e-3   # int8 EF noise only
