"""Quantized-LUT (int8) search stack (DESIGN.md §8): calibration
round-trip error bound, quantized lut_sum vs the dequantized reference,
pallas==jnp parity for the int8 crude kernels, query-chunk invariance,
the int8-vs-f32 recall@10 gap on the seed config, and sharded int8
merge identity (subprocess under 4 forced host devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebooks as cb
from repro.core import icq as icq_mod
from repro.index import (adc_search, build_ivf, build_lut,
                         ivf_two_step_search, lut_sum, quantize_lut,
                         two_step_search)


def _problem(key, n, nq, K=4, m=16, kf=2, d=8, sigma=1.0):
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


# ---------------------------------------------------------- calibration ----

def test_quantize_lut_roundtrip_error_bound(key):
    """Every kept entry dequantizes to within scale/2 of its f32 value
    (the affine-calibration guarantee a sum of S entries inherits as
    S * scale / 2)."""
    C = jax.random.normal(key, (6, 32, 8)) * 0.4
    q = jax.random.normal(jax.random.fold_in(key, 1), (5, 8))
    luts = build_lut(q, C)
    mask = jnp.zeros((6,), bool).at[:2].set(True)
    for cb_mask in (None, mask):
        ql = quantize_lut(luts, cb_mask)
        deq = (ql.scale[:, None, None] * ql.q.astype(jnp.float32)
               + ql.bias[:, None, None])
        keep = (jnp.ones(luts.shape, bool) if cb_mask is None
                else jnp.broadcast_to(cb_mask[None, :, None], luts.shape))
        err = jnp.max(jnp.abs(jnp.where(keep, deq - luts, 0.0)), axis=(1, 2))
        # scale/2 plus a float-rounding epsilon
        assert (np.asarray(err) <= np.asarray(ql.scale) / 2 + 1e-5).all()
    # the fast-subset calibration must be at least as tight
    assert float(jnp.max(quantize_lut(luts, mask).scale)) <= \
        float(jnp.max(quantize_lut(luts).scale)) + 1e-12
    # single-query (K, m) tables quantize too
    ql1 = quantize_lut(luts[0])
    assert ql1.q.shape == luts[0].shape and ql1.scale.ndim == 0


def test_quantize_lut_constant_table_guard(key):
    """A degenerate all-equal table must not divide by zero."""
    luts = jnp.ones((3, 4, 8))
    ql = quantize_lut(luts)
    assert np.isfinite(np.asarray(ql.scale)).all()
    deq = (ql.scale[:, None, None] * ql.q.astype(jnp.float32)
           + ql.bias[:, None, None])
    np.testing.assert_allclose(np.asarray(deq), 1.0, atol=1e-5)


def test_lut_sum_quantized_matches_dequantized_reference(key):
    """Integer accumulation + one rescale == summing the dequantized
    f32 table, for all three lut_sum shape cases."""
    K, m, n, nq, t = 5, 16, 200, 4, 9
    C = jax.random.normal(key, (K, m, 8)) * 0.3
    q = jax.random.normal(jax.random.fold_in(key, 1), (nq, 8))
    luts = build_lut(q, C)
    codes = jax.random.randint(jax.random.fold_in(key, 2), (n, K), 0, m)
    cand = jax.random.randint(jax.random.fold_in(key, 3), (nq, t, K), 0, m)
    mask = jnp.zeros((K,), bool).at[:2].set(True)
    for cb_mask in (None, mask):
        ql = quantize_lut(luts, cb_mask)
        keep = (jnp.ones((K,), bool) if cb_mask is None else cb_mask)
        deq = jnp.where(
            keep[None, :, None],
            ql.scale[:, None, None] * ql.q.astype(jnp.float32)
            + ql.bias[:, None, None], 0.0)
        # shared database codes
        got = lut_sum(ql, codes, cb_mask)
        want = lut_sum(deq, codes)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)
        # per-query candidate codes
        got_c = lut_sum(ql, cand, cb_mask)
        want_c = lut_sum(deq, cand)
        np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                                   atol=1e-4)
    # single-query case
    ql0 = quantize_lut(luts[0])
    got0 = lut_sum(ql0, codes)
    deq0 = (ql0.scale * ql0.q.astype(jnp.float32) + ql0.bias)
    np.testing.assert_allclose(np.asarray(got0),
                               np.asarray(lut_sum(deq0, codes)), atol=1e-4)


# --------------------------------------------------------------- parity ----

@pytest.mark.parametrize("n,nq,K,m,kf", [
    (257, 5, 4, 16, 1),      # non-divisible n/nq, |K_fast| = 1
    (530, 7, 8, 32, 7),      # |K_fast| = K - 1
])
def test_two_step_int8_pallas_matches_jnp(key, n, nq, K, m, kf):
    """int8 crude kernel == int8 jnp engine: exact ids, 1e-4 distances,
    identical pass accounting (both dequantize with the same affine)."""
    q, codes, C, st = _problem(jax.random.fold_in(key, n), n, nq, K=K,
                               m=m, kf=kf)
    topk = 17
    r_j = two_step_search(q, codes, C, st, topk, backend="jnp",
                          lut_dtype="int8")
    r_p = two_step_search(q, codes, C, st, topk, backend="pallas",
                          interpret=True, block_q=3, block_n=200,
                          lut_dtype="int8")
    np.testing.assert_array_equal(np.asarray(r_p.indices),
                                  np.asarray(r_j.indices))
    np.testing.assert_allclose(np.asarray(r_p.distances),
                               np.asarray(r_j.distances), atol=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-6)


def test_adc_int8_pallas_matches_jnp(key):
    q, codes, C, st = _problem(key, 300, 6)
    r_j = adc_search(q, codes, C, 12, backend="jnp", lut_dtype="int8")
    r_p = adc_search(q, codes, C, 12, backend="pallas", interpret=True,
                     block_q=4, block_n=128, lut_dtype="int8")
    np.testing.assert_array_equal(np.asarray(r_j.indices),
                                  np.asarray(r_p.indices))
    np.testing.assert_allclose(np.asarray(r_j.distances),
                               np.asarray(r_p.distances), atol=1e-4)


def test_ivf_int8_pallas_matches_jnp(key):
    q, codes, C, st = _problem(key, 911, 6, sigma=2.0)
    emb = cb.decode(C, codes)
    ivf = build_ivf(jax.random.fold_in(key, 3), emb, 16)
    r_j = ivf_two_step_search(q, codes, C, st, ivf, 17, 4, backend="jnp",
                              lut_dtype="int8")
    r_p = ivf_two_step_search(q, codes, C, st, ivf, 17, 4,
                              backend="pallas", interpret=True,
                              block_q=4, block_n=96, lut_dtype="int8")
    np.testing.assert_array_equal(np.asarray(r_p.indices),
                                  np.asarray(r_j.indices))
    np.testing.assert_allclose(np.asarray(r_p.distances),
                               np.asarray(r_j.distances), atol=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-6)


def test_int8_query_chunk_invariant(key):
    """Calibration is per-query, so chunking cannot change results."""
    q, codes, C, st = _problem(key, 400, 11)
    r_full = two_step_search(q, codes, C, st, 9, backend="jnp",
                             lut_dtype="int8")
    r_chunk = two_step_search(q, codes, C, st, 9, backend="jnp",
                              lut_dtype="int8", query_chunk=3)
    np.testing.assert_array_equal(np.asarray(r_full.indices),
                                  np.asarray(r_chunk.indices))
    np.testing.assert_allclose(np.asarray(r_full.distances),
                               np.asarray(r_chunk.distances), rtol=1e-6)


def test_int8_refine_cap_engages(key):
    """refine_cap + int8: the cap path re-ranks survivors by *exact*
    full distances (quantization only selects); distances come back
    sorted and the pass accounting matches the dense int8 engine."""
    q, codes, C, st = _problem(key, 400, 7, sigma=3.0)
    r_dense = two_step_search(q, codes, C, st, 9, backend="jnp",
                              lut_dtype="int8")
    r_cap = two_step_search(q, codes, C, st, 9, backend="jnp",
                            lut_dtype="int8", refine_cap=12)
    d = np.asarray(r_cap.distances)
    assert (np.diff(d, axis=1)[np.isfinite(d[:, 1:])] >= 0).all()
    assert float(r_cap.pass_rate) == pytest.approx(
        float(r_dense.pass_rate), abs=1e-6)


def test_lut_dtype_validation(key):
    q, codes, C, st = _problem(key, 64, 3)
    with pytest.raises(ValueError):
        two_step_search(q, codes, C, st, 5, backend="jnp",
                        lut_dtype="fp16")
    with pytest.raises(ValueError):
        adc_search(q, codes, C, 5, backend="jnp", lut_dtype="bf16")
    # the kernels reject mismatched quantization operands outright
    from repro.kernels.batched_search import crude_topk_pallas
    luts = build_lut(q, C).reshape(q.shape[0], -1)
    with pytest.raises(ValueError):
        crude_topk_pallas(codes, luts, jnp.ones((q.shape[0],)), None,
                          topk=5, interpret=True)
    with pytest.raises(ValueError):
        crude_topk_pallas(codes, luts.astype(jnp.int8), topk=5,
                          interpret=True)


# ----------------------------------------------------------- seed config ----

def test_int8_recall_gap_on_seed_config():
    """Acceptance: on a fitted seed-config model the int8 crude pass
    costs <= 0.01 recall@10 (vs exact L2 over the embedded database)
    relative to the f32 engine."""
    from repro.configs.base import ICQConfig
    from repro.core import fit
    from repro.data import make_table1_dataset
    from repro.index import exact_search, recall_at

    xtr, ytr, xte, _ = make_table1_dataset("dataset3")
    xtr, ytr, xte = xtr[:1500], ytr[:1500], xte[:64]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=32, num_fast=2)
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=3,
                batch_size=256)
    emb_q, emb_db = model.embed(xte), model.embed(xtr)
    gt, _ = exact_search(emb_q, emb_db, 10)
    rec = {}
    for lut_dtype in ("f32", "int8"):
        r = two_step_search(emb_q, model.codes, model.C, model.structure,
                            20, backend="jnp", lut_dtype=lut_dtype)
        rec[lut_dtype] = float(recall_at(r.indices[:, :10], gt))
    assert abs(rec["f32"] - rec["int8"]) <= 0.01, rec


# ------------------------------------------------------------- sharding ----

_SHARDED_INT8_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.index import FlatADC, IVFTwoStep, TwoStep

    key = jax.random.PRNGKey(0)
    n, nq, K, m, d, kf = 1237, 9, 4, 16, 8, 2
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    emb = cb.decode(C, codes)
    mesh = jax.make_mesh((4,), ("data",))

    def check(idx, tag):
        r1, r4 = idx.search(q), idx.shard(mesh).search(q)
        np.testing.assert_array_equal(np.asarray(r1.indices),
                                      np.asarray(r4.indices), err_msg=tag)
        np.testing.assert_allclose(np.asarray(r1.distances),
                                   np.asarray(r4.distances), atol=1e-5,
                                   err_msg=tag)
        assert float(r1.pass_rate) == float(r4.pass_rate), tag

    check(FlatADC.build(codes, C, topk=17, backend="jnp",
                        lut_dtype="int8"), "flat-int8")
    check(TwoStep.build(codes, C, st, topk=17, backend="jnp",
                        lut_dtype="int8"), "two-step-int8")
    for n_lists, n_probe, cap in [(16, 4, None), (13, 5, None),
                                  (16, 4, 20)]:
        idx = IVFTwoStep.build(codes, C, st, emb_db=emb,
                               key=jax.random.fold_in(key, 3),
                               n_lists=n_lists, n_probe=n_probe, topk=17,
                               backend="jnp", refine_cap=cap,
                               lut_dtype="int8")
        check(idx, f"ivf-int8-{n_lists}-{n_probe}-{cap}")
    print("SHARDED_INT8_OK")
""")


def test_sharded_int8_merge_identity():
    """Sharded serving under lut_dtype="int8": ids bitwise-identical to
    the single-device int8 engines (the query-global calibration makes
    per-shard dequantized distances merge-comparable).  Subprocess: the
    in-process suite must keep seeing one device (conftest)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_INT8_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_INT8_OK" in proc.stdout
