"""Per-arch reduced-config smoke: one train step + prefill/decode on CPU,
asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import build_model


def make_batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    s_text = s - (cfg.num_vision_tokens if cfg.frontend == "vision_stub" else 0)
    tokens = jax.random.randint(key, (b, s_text), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision_stub":
        batch["patch_emb"] = jax.random.normal(
            key, (b, cfg.num_vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.encdec:
        batch["audio_emb"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_train_step(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    (loss, aux), grads = jax.jit(jax.value_and_grad(
        m.train_forward, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_prefill_decode(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, cache = jax.jit(lambda p, bt: m.prefill(p, bt, 32))(params, batch)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    tok = batch["tokens"][:, -1:]
    step = jax.jit(m.decode_step)
    logits2, cache = step(params, tok, cache)
    logits3, cache = step(params, tok, cache)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits3.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_prefill_decode_consistency(arch):
    """Decoding token t+1 after prefill(0..t) must match a longer prefill's
    last-position logits (cache correctness across the two paths)."""
    cfg = smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (1, 17), 0, cfg.vocab_size)
    full = {"tokens": toks, "labels": toks}
    part = {"tokens": toks[:, :16], "labels": toks[:, :16]}
    logits_full, _ = jax.jit(lambda p, bt: m.prefill(p, bt, 32))(params, full)
    _, cache = jax.jit(lambda p, bt: m.prefill(p, bt, 32))(params, part)
    logits_step, _ = jax.jit(m.decode_step)(params, toks[:, 16:17], cache)
    np.testing.assert_allclose(np.asarray(logits_step[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-3)
