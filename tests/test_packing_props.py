"""Property tests for the code-packing layer: ``pack_codes`` /
``unpack_codes`` width selection (uint8 / uint16 / int32) and
``pack_nibbles`` / ``unpack_nibbles`` round trips over arbitrary
(n, K, m) geometries, including the odd-K sentinel nibble and batched
candidate shapes.

Runs under Hypothesis when it is installed (CI installs it); otherwise
falls back to a seeded random-case shim with the same generators so the
properties stay exercised in minimal environments — the strategy space,
not the framework, is the point.
"""
import numpy as np
import pytest

from repro.core.encode import (pack_codes, pack_nibbles, unpack_codes,
                               unpack_nibbles)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI has it
    HAVE_HYPOTHESIS = False

    class _Draw:
        """Minimal stand-in for a Hypothesis draw: seeded numpy rng."""

        def __init__(self, rng):
            self.rng = rng

        def ints(self, lo, hi):
            return int(self.rng.integers(lo, hi + 1))

    def _fallback_cases(f, n_cases=100):
        def wrapper():
            for case in range(n_cases):
                f(_Draw(np.random.default_rng(1000 + case)))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

if HAVE_HYPOTHESIS:
    class _Draw:
        """Adapter so the same test body serves both frameworks."""

        def __init__(self, data):
            self.data = data

        def ints(self, lo, hi):
            return self.data.draw(st.integers(lo, hi))

    def _fallback_cases(f, n_cases=100):
        @settings(max_examples=n_cases, deadline=None)
        @given(st.data())
        def wrapper(data):
            f(_Draw(data))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper


def _codes(draw, n, K, m):
    rng = np.random.default_rng(draw.ints(0, 2 ** 31))
    return rng.integers(0, m, size=(n, K)).astype(np.int32)


@pytest.fixture(scope="module")
def _jnp():
    import jax.numpy as jnp
    return jnp


def _pack_codes_round_trip(draw):
    """pack_codes narrows to the smallest width that fits m and
    unpack_codes restores the exact values, for any (n, K, m)."""
    import jax.numpy as jnp
    n = draw.ints(1, 64)
    K = draw.ints(1, 12)
    m = draw.ints(2, 70_000)
    codes = _codes(draw, n, K, m)
    packed = pack_codes(jnp.asarray(codes), m)
    want = jnp.uint8 if m <= 256 else (jnp.uint16 if m <= 65536
                                       else jnp.int32)
    assert packed.dtype == want
    restored = unpack_codes(packed)
    assert restored.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(restored), codes)


def _pack_nibbles_round_trip(draw):
    """(n, K) -> (n, ceil(K/2)) uint8 -> (n, K) is exact for every
    K >= 1 and m <= 16; odd K keeps a zero sentinel in the final high
    nibble."""
    import jax.numpy as jnp
    n = draw.ints(1, 64)
    K = draw.ints(1, 17)
    m = draw.ints(2, 16)
    codes = _codes(draw, n, K, m)
    packed = pack_nibbles(jnp.asarray(codes), K)
    assert packed.shape == (n, (K + 1) // 2)
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(packed, K)), codes)
    if K % 2:
        assert int(np.max(np.asarray(packed)[:, -1] >> 4)) == 0


def _pack_nibbles_batched_shapes(draw):
    """The candidate-tensor layout (nq, t, K) round-trips identically —
    packing is pointwise over the trailing axis."""
    import jax.numpy as jnp
    nq = draw.ints(1, 6)
    t = draw.ints(1, 9)
    K = draw.ints(1, 11)
    rng = np.random.default_rng(draw.ints(0, 2 ** 31))
    cand = rng.integers(0, 16, size=(nq, t, K)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(pack_nibbles(jnp.asarray(cand), K), K)),
        cand)


def _pack_nibbles_rejects_wrong_k(draw):
    """K must match the trailing axis — any mismatch raises."""
    import jax.numpy as jnp
    K = draw.ints(1, 10)
    wrong = draw.ints(1, 11)
    if wrong == K:
        wrong += 1
    codes = _codes(draw, 8, K, 16)
    with pytest.raises(ValueError, match="pack_nibbles"):
        pack_nibbles(jnp.asarray(codes), wrong)


test_pack_codes_round_trip = _fallback_cases(_pack_codes_round_trip, 60)
test_pack_nibbles_round_trip = _fallback_cases(_pack_nibbles_round_trip,
                                               100)
test_pack_nibbles_batched_shapes = _fallback_cases(
    _pack_nibbles_batched_shapes, 60)
test_pack_nibbles_rejects_wrong_k = _fallback_cases(
    _pack_nibbles_rejects_wrong_k, 30)
