"""Correctness of the §Perf mechanisms: two-level remat must not change
gradients; the ICQ-KV decode plan and cross-pod combine programs lower
and stay numerically faithful."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.sharding import make_mesh_auto, shard_map_compat
from repro.models import build_model


def test_sqrt_remat_same_loss_and_grads():
    """remat_block (two-level checkpointing) is a pure memory/computation
    trade — loss and gradients must match the flat-remat path exactly."""
    base = dataclasses.replace(smoke_config("tinyllama-1.1b"),
                               num_layers=4, remat=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              base.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    outs = {}
    for G in (0, 2):
        cfg = dataclasses.replace(base, remat_block=G)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        (loss, _), grads = jax.jit(jax.value_and_grad(
            model.train_forward, has_aux=True))(params, batch)
        outs[G] = (float(loss), grads)
    assert outs[0][0] == pytest.approx(outs[2][0], rel=1e-6)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         outs[0][1], outs[2][1])
    assert max(jax.tree.leaves(diffs)) < 1e-5


def test_icq_kv_decode_step_runs():
    """The ICQ-KV decode step (quant/serve_icq.py) produces finite logits
    and advances its quantized caches."""
    from repro.quant.kv_cache import ICQKVConfig
    from repro.quant.serve_icq import build_icq_decode, supports_icq_kv
    cfg = smoke_config("tinyllama-1.1b")
    assert supports_icq_kv(cfg)
    kv_cfg = ICQKVConfig(d_fast=8)
    decode, init_cache = build_icq_decode(cfg, kv_cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = init_cache(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(lambda p, t, c: decode(p, t, c, top_c=8))
    logits, caches = step(params, tok, caches)
    logits2, caches = step(params, tok, caches)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(caches["pos"]) == 2


def test_combine_programs_numerics():
    """int8 EF combine over a singleton pod axis == dequant(quant(g))."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.combine import _combine_fp32, _combine_int8
    from repro.quant.grad_compress import ef_quantize
    from repro.quant.int8 import dequantize_int8
    mesh = make_mesh_auto((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.01
    r = jnp.zeros_like(g)
    for fn in (_combine_fp32, _combine_int8):
        out, _ = jax.jit(shard_map_compat(
            fn, mesh, (P(), P()), (P(), P())))(g, r)
        if fn is _combine_fp32:
            np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                                       atol=1e-7)
        else:
            q, s, _ = ef_quantize(g, r)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(dequantize_int8(q, s)),
                atol=1e-6)


def test_icq_kv_plan_lowers_on_tiny_mesh():
    from repro.configs.base import ShapeSpec
    from repro.launch.steps import lower_cell, plan_icq_kv_cell
    cfg = dataclasses.replace(smoke_config("tinyllama-1.1b"), head_dim=64)
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    shape = ShapeSpec("d", seq_len=256, global_batch=2, kind="decode")
    plan = plan_icq_kv_cell(cfg, shape, mesh)
    compiled = lower_cell(plan).compile()
    assert compiled is not None


def test_shard_local_two_step_matches_global():
    """Context-parallel ICQ-KV: combining per-shard (m, l, o) partials
    reproduces the global two-step result when the shard-local candidate
    budgets sum to the global top_c (small diff = different-but-equal-
    size candidate sets)."""
    from repro.quant import ICQKVConfig, build_icq_kv_cache
    from repro.quant.kv_cache import (combine_partials_local,
                                      icq_kv_attention_partial,
                                      icq_kv_decode_attention)
    key = jax.random.PRNGKey(0)
    b, s, kvh, g, dh = 2, 512, 4, 2, 64
    scale = jnp.concatenate([jnp.ones(8) * 3.0, jnp.ones(dh - 8) * 0.3])
    perm = jax.random.permutation(key, dh)
    k = jax.random.normal(key, (b, s, kvh, dh)) * scale[perm]
    v = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, dh))
    q = (jax.random.normal(jax.random.fold_in(key, 2), (b, 1, kvh * g, dh))
         * scale[perm])
    cfg = ICQKVConfig(d_fast=16)
    cache = build_icq_kv_cache(cfg, k, v, max_len=s)
    pos = s - 1
    glob = icq_kv_decode_attention(q, cache, cfg, pos, top_c=128)[:, 0]
    glob = glob.reshape(b, kvh, g, dh).astype(jnp.float32)
    parts = []
    for sh in range(4):
        sl = {kk: (vv[:, sh * 128:(sh + 1) * 128]
                   if vv.ndim >= 3 and vv.shape[1] == s else vv)
              for kk, vv in cache.items()}
        parts.append(icq_kv_attention_partial(q, sl, cfg, pos, 32,
                                              shard_offset=sh * 128))
    out = combine_partials_local(*(jnp.stack(t) for t in zip(*parts)))
    err = float(jnp.abs(out - glob).max())
    assert err < 0.15 * float(jnp.abs(glob).std()) + 0.05
