"""ICQ-KV cache + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CI installs it
from hypothesis import given, settings, strategies as st

from repro.quant import (ICQKVConfig, build_icq_kv_cache, dequantize_int8,
                         icq_kv_append, icq_kv_decode_attention,
                         quantize_int8)
from repro.quant.grad_compress import compress_state_init, ef_quantize
from repro.distributed.sharding import make_mesh_auto, shard_map_compat
from repro.quant.kv_cache import reference_decode_attention


def _structured_kv(key, b, s, kvh, dh, hot=8):
    """Keys with a high-variance subspace — ICQ's favorable regime."""
    scale = jnp.concatenate([jnp.ones(hot) * 3.0, jnp.ones(dh - hot) * 0.3])
    perm = jax.random.permutation(key, dh)
    k = jax.random.normal(key, (b, s, kvh, dh)) * scale[perm]
    v = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, dh))
    return k, v, scale[perm]


def test_int8_roundtrip_error_bounded(key):
    x = jax.random.normal(key, (32, 64)) * 5
    q, s = quantize_int8(x)
    rec = dequantize_int8(q, s)
    # symmetric int8: error <= scale/2 = max|row|/254 per element
    bound = np.asarray(jnp.max(jnp.abs(x), -1, keepdims=True)) / 127.0
    assert (np.abs(np.asarray(rec - x)) <= bound / 2 + 1e-6).all()


def test_icq_kv_close_to_exact(key):
    """Top-c pruning is accurate when attention is concentrated (the
    trained-model regime; uniform attention is the worst case for ANY
    top-k attention scheme) — so queries share the keys' hot subspace."""
    b, s, kvh, g, dh = 2, 512, 4, 2, 64
    k, v, dim_scale = _structured_kv(key, b, s, kvh, dh)
    q = (jax.random.normal(jax.random.fold_in(key, 2), (b, 1, kvh * g, dh))
         * dim_scale)
    cfg = ICQKVConfig(d_fast=16)
    cache = build_icq_kv_cache(cfg, k, v, max_len=s)
    ref = reference_decode_attention(q, k, v, s - 1)
    rels = []
    for tc in (64, 128, 256):
        out = icq_kv_decode_attention(q, cache, cfg, s - 1, top_c=tc)
        rels.append(float(jnp.abs(out - ref).max() / jnp.abs(ref).std()))
    # error shrinks monotonically with the survivor budget and is small
    # at top_c = S/4 (remaining error = dropped softmax tail + int8)
    assert rels[0] > rels[1] > rels[2]
    assert rels[1] < 0.35 and rels[2] < 0.15


def test_icq_kv_perm_is_variance_ordered(key):
    b, s, kvh, dh = 1, 256, 2, 32
    k, v, scales = _structured_kv(key, b, s, kvh, dh, hot=4)
    cfg = ICQKVConfig(d_fast=4)
    cache = build_icq_kv_cache(cfg, k, v, max_len=s)
    hot_dims = set(np.argsort(-np.asarray(scales))[:4])
    for h in range(kvh):
        got = set(np.asarray(cache["perm"][h][:4]))
        assert got == hot_dims


def test_icq_kv_append_consistency(key):
    b, s, kvh, g, dh = 1, 128, 2, 2, 32
    k, v, _ = _structured_kv(key, b, s, kvh, dh)
    cfg = ICQKVConfig(d_fast=8)
    cache = build_icq_kv_cache(cfg, k[:, :96], v[:, :96], max_len=s)
    for pos in range(96, 128):
        cache = icq_kv_append(cache, cfg, k[:, pos:pos+1], v[:, pos:pos+1], pos)
    full = build_icq_kv_cache(cfg, k, v, max_len=s)
    # same quantized contents regardless of build path (same perm source
    # domain: perms may differ -> compare attention outputs instead)
    q = jax.random.normal(jax.random.fold_in(key, 9), (b, 1, kvh * g, dh))
    o1 = icq_kv_decode_attention(q, cache, cfg, 127, top_c=32)
    o2 = icq_kv_decode_attention(q, full, cfg, 127, top_c=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=0.15, atol=0.05)


def test_icq_kv_full_topc_matches_int8_exact(key):
    """top_c = S disables pruning: result equals attention over the int8
    dequantized cache (quantization error only)."""
    b, s, kvh, g, dh = 1, 64, 2, 2, 16
    k, v, _ = _structured_kv(key, b, s, kvh, dh, hot=4)
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, 1, kvh * g, dh))
    cfg = ICQKVConfig(d_fast=16)
    cache = build_icq_kv_cache(cfg, k, v, max_len=s)
    out = icq_kv_decode_attention(q, cache, cfg, s - 1, top_c=s)
    ref = reference_decode_attention(q, k, v, s - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0.05, atol=0.05)


# --------------------------------------------------------- grad compress --

def test_ef_residual_carries_quantization_error(key):
    g = jax.random.normal(key, (64, 32)) * 0.01
    q, s, r = ef_quantize(g, jnp.zeros_like(g))
    rec = dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(rec + r), np.asarray(g), atol=1e-7)


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 20))
def test_ef_accumulation_unbiased(n_steps):
    """Property (EF-SGD): sum of dequantized updates + final residual ==
    sum of true gradients, exactly — compression never loses mass."""
    key = jax.random.PRNGKey(n_steps)
    r = jnp.zeros((16, 8))
    acc_q = jnp.zeros((16, 8))
    acc_t = jnp.zeros((16, 8))
    for i in range(n_steps):
        g = jax.random.normal(jax.random.fold_in(key, i), (16, 8)) * 0.1
        acc_t = acc_t + g
        q, s, r = ef_quantize(g, r)
        acc_q = acc_q + dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc_q + r), np.asarray(acc_t),
                               atol=1e-5)


def test_compressed_cross_pod_mean_single_pod(key):
    """Under a 1-sized pod axis the compressed mean must reproduce the
    dequantized local gradient (wire format check via shard_map)."""
    from jax.sharding import PartitionSpec as P
    from repro.quant.grad_compress import compressed_cross_pod_mean
    mesh = make_mesh_auto((1,), ("pod",))
    g = {"w": jax.random.normal(key, (8, 4))}
    res = compress_state_init(g)

    def f(g, r):
        return compressed_cross_pod_mean(g, r, axis_name="pod")

    out, new_res = jax.jit(shard_map_compat(
        f, mesh, (P(), P()), (P(), P())))(g, res)
    q, s, _ = ef_quantize(g["w"], res["w"])
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(dequantize_int8(q, s)), atol=1e-6)
