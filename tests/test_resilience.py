"""Resilient serving (docs/robustness.md): the deterministic fault
injector, bounded-backoff retries, the degradation ladder and its
crude-only bitwise parity, Pallas→jnp failover, dead-shard merge
(subprocess on 4 forced devices), artifact integrity (interrupted
saves, corrupted tensors rejected by name), and supervised training
resume — in-process fault replay and a SIGKILL-and-resume subprocess
smoke, both asserting bitwise-identical final codebooks."""
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import build_ann_engine
from repro.core import codebooks as cb_mod
from repro.core import icq as icq_mod
from repro.resilience import (BackoffPolicy, FaultInjector, FaultSpec,
                              InjectedFault, RetriesExhausted, SearchBudget,
                              retry_with_backoff)


def _problem(key, n=400, nq=6, K=4, m=16, kf=2, d=8):
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


@pytest.fixture(scope="module")
def prob():
    return _problem(jax.random.PRNGKey(0))


def _engine(prob, kind, backend, **kw):
    q, codes, C, st = prob
    if kind == "ivf":
        kw.setdefault("emb_db", cb_mod.decode(C, codes))
        kw.setdefault("n_lists", 8)
        kw.setdefault("n_probe", 4)
        kw.setdefault("key", jax.random.PRNGKey(3))
    return build_ann_engine(codes, C, st, topk=10, backend=backend,
                            index=kind, **kw)


# ------------------------------------------------------- fault injector ----

def test_injector_deterministic():
    spec = FaultSpec(p_raise=0.3, p_delay=0.2, delay_ms=0.0)
    seqs = []
    for _ in range(2):
        inj = FaultInjector(seed=7, spec=spec, sleep=lambda s: None)
        fates = []
        for i in range(50):
            try:
                inj.check(f"kernels.stage{i % 3}")
                fates.append("ok")
            except InjectedFault:
                fates.append("raise")
        seqs.append((tuple(fates), dict(inj.counts)))
    assert seqs[0] == seqs[1]
    assert any(f == "raise" for f in seqs[0][0])


def test_injector_targets_and_corruption():
    inj = FaultInjector(seed=0, spec=FaultSpec(p_raise=1.0,
                                               targets=("kernels.",)))
    inj.check("engine.search")          # not targeted: no fault
    with pytest.raises(InjectedFault):
        inj.check("kernels.adc")
    a = np.arange(64, dtype=np.float32)
    b = FaultInjector(seed=1).corrupt_array(a)
    assert a.shape == b.shape and a.dtype == b.dtype
    assert not np.array_equal(a, b)
    # same seed, same flips
    b2 = FaultInjector(seed=1).corrupt_array(a)
    np.testing.assert_array_equal(b, b2)


def test_retry_schedule_and_exhaustion():
    pol = BackoffPolicy(max_retries=3, base_ms=10.0, max_ms=25.0)
    assert [pol.delay_ms(i) for i in range(4)] == [10.0, 20.0, 25.0, 25.0]

    calls = {"n": 0}
    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"
    slept = []
    assert retry_with_backoff(flaky, policy=pol,
                              sleep=slept.append) == "ok"
    assert calls["n"] == 3 and len(slept) == 2

    def always():
        raise OSError("down")
    with pytest.raises(RetriesExhausted) as ei:
        retry_with_backoff(always, policy=BackoffPolicy(max_retries=1),
                           sleep=lambda s: None)
    assert isinstance(ei.value.__cause__, OSError)


def test_budget_validation():
    for bad in (SearchBudget(deadline_ms=0),
                SearchBudget(max_n_probe=0),
                SearchBudget(refine_cap=0),
                SearchBudget(force_level="fastest")):
        with pytest.raises(ValueError):
            from repro.resilience.budget import validate_budget
            validate_budget(bad)


# ------------------------------------------------ degraded-path parity ----

@pytest.mark.parametrize("kind", ["flat", "two-step", "ivf"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_crude_budget_bitwise_parity(prob, kind, backend):
    """A crude-only budget result must be bitwise-identical to the
    crude ranking the full path computes internally on the same
    backend (same computation, same jit regime)."""
    q = prob[0]
    eng = _engine(prob, kind, backend)
    r = eng.search(q, budget=SearchBudget(allow_refine=False))
    assert r.meta.level_name == "crude" and r.meta.degraded
    ref = jax.jit(lambda x: eng.index.search_crude(x))(q)
    np.testing.assert_array_equal(np.asarray(r.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(r.distances),
                                  np.asarray(ref.distances))


def test_ladder_deadline_degrades_and_recovers(prob):
    q = prob[0]
    eng = _engine(prob, "two-step", "jnp")
    for _ in range(3):                   # warm the full rung's EMA
        assert eng.search(q).meta.level_name == "full"
    tight = eng.search(q, budget=SearchBudget(deadline_ms=1e-6))
    assert tight.meta.level_name == "crude" and tight.meta.degraded
    assert tight.meta.stages == ("crude",)
    generous = eng.search(q, budget=SearchBudget(deadline_ms=1e9))
    assert generous.meta.level_name == "full" and not generous.meta.degraded


def test_ladder_caps_promote_rungs(prob):
    q = prob[0]
    eng = _engine(prob, "ivf", "jnp")
    capped = eng.search(q, budget=SearchBudget(refine_cap=32))
    assert capped.meta.level_name == "capped"
    probes = eng.search(q, budget=SearchBudget(max_n_probe=2))
    assert probes.meta.level_name == "probes"
    # full (untouched by budget) still serves exact
    full = eng.search(q)
    assert full.meta.level_name == "full" and full.meta.stages == \
        ("probe", "crude", "refine")


def test_meta_attached_and_wall_measured(prob):
    q = prob[0]
    eng = _engine(prob, "two-step", "jnp")
    r = eng.search(q)
    assert r.meta is not None and r.meta.wall_ms > 0.0
    assert r.meta.coverage == 1.0 and r.meta.backend == "jnp"
    assert eng.stats["full"] >= 1


# --------------------------------------------------------- failover ----

def test_pallas_fault_fails_over_to_jnp(prob):
    """An injected Pallas kernel fault blacklists the backend; the
    batch is served via jnp and matches a clean jnp engine."""
    q = prob[0]
    inj = FaultInjector(seed=0,
                        spec=FaultSpec(p_raise=1.0, targets=("kernels.",)))
    eng = _engine(prob, "two-step", "pallas", fault_injector=inj)
    with inj.installed():
        r = eng.search(q)
    assert eng.stats["failovers"] == 1
    assert r.meta.backend == "jnp"
    ref = _engine(prob, "two-step", "jnp").search(q)
    np.testing.assert_array_equal(np.asarray(r.indices),
                                  np.asarray(ref.indices))
    # backend stays blacklisted: no new failover on the next batch
    r2 = eng.search(q)
    assert r2.meta.backend == "jnp" and eng.stats["failovers"] == 1


def test_jnp_transient_fault_retries(prob):
    """engine.search-stage faults on the jnp path retry in place; a
    permanent fault exhausts the bounded retries."""
    q = prob[0]
    inj = FaultInjector(seed=0, spec=FaultSpec(p_raise=1.0,
                                               targets=("engine.search",)))
    from repro.api import ResilienceConfig
    eng = _engine(prob, "flat", "jnp", fault_injector=inj,
                  resilience=ResilienceConfig(max_retries=1,
                                              backoff_base_ms=0.001))
    with pytest.raises(RetriesExhausted):
        eng.search(q)


# ------------------------------------------------------- dead shards ----

_DEAD_SHARD_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 4, jax.devices()
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.index import FlatADC, IVFTwoStep, TwoStep

    key = jax.random.PRNGKey(0)
    n, nq, K, m, d, kf = 1237, 9, 4, 16, 8, 2
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    emb = cb.decode(C, codes)
    mesh = jax.make_mesh((4,), ("data",))
    topk = 17

    per = -(-n // 4)                          # rows per shard (row kinds)

    for build, tag in [
        (lambda: FlatADC.build(codes, C, topk=topk, backend="jnp"), "flat"),
        (lambda: TwoStep.build(codes, C, st, topk=topk, backend="jnp"),
         "two-step"),
    ]:
        view = build().shard(mesh).mark_shard_dead(2)
        r = view.search(q)
        assert 0.7 < view.coverage < 0.8, (tag, view.coverage)
        lost = set(range(2 * per, min(3 * per, n)))
        ids = np.asarray(r.indices)
        assert not (set(ids.ravel().tolist()) & lost), tag
        # restricted parity: single-device search over the surviving
        # rows only must give the same ids/distances
        keep = np.array(sorted(set(range(n)) - lost))
        codes_s = jnp.asarray(np.asarray(codes)[keep])
        if tag == "flat":
            ref = FlatADC.build(codes_s, C, topk=topk,
                                backend="jnp").search(q)
        else:
            ref = TwoStep.build(codes_s, C, st, topk=topk,
                                backend="jnp").search(q)
        np.testing.assert_array_equal(keep[np.asarray(ref.indices)], ids,
                                      err_msg=tag)
        np.testing.assert_allclose(np.asarray(ref.distances),
                                   np.asarray(r.distances), atol=1e-5,
                                   err_msg=tag)

    # IVF: list-sharded (rows hash to lists), so exact restricted parity
    # has no single-device analogue; assert the contract instead —
    # no raise, coverage < 1, and no id from a dead shard's lists
    idx = IVFTwoStep.build(codes, C, st, emb_db=emb,
                           key=jax.random.fold_in(key, 3), n_lists=16,
                           n_probe=16, topk=topk, backend="jnp")
    view = idx.shard(mesh).mark_shard_dead(1)
    r = view.search(q)
    assert 0.5 < view.coverage < 1.0, view.coverage
    Ls = 16 // 4                              # list rows per shard
    dead_ids = set(np.asarray(idx.ivf.lists)[Ls:2 * Ls].ravel()
                   .tolist()) - {-1}
    got = set(np.asarray(r.indices).ravel().tolist()) - {-1}
    assert not (got & dead_ids)

    # killing every shard is an error, not a silent empty result
    try:
        view.mark_shard_dead(0, 2, 3)
        raise SystemExit("expected ValueError for all-dead")
    except ValueError:
        pass
    print("DEAD_SHARD_OK")
""")


def test_dead_shard_merge_subprocess():
    """Dead-shard failover on a forced 4-device host: survivors' merge
    equals the single-device search restricted to surviving rows, and
    coverage reports the reachable fraction (subprocess: this suite
    must keep seeing one device, see conftest)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _DEAD_SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DEAD_SHARD_OK" in proc.stdout


# -------------------------------------------------- artifact integrity ----

def _small_artifacts(tmp_path, v=0.0):
    from repro.api import Artifacts, ICQConfig, IndexConfig
    from repro.index import FlatADC
    key = jax.random.PRNGKey(0)
    C = jax.random.normal(key, (2, 4, 4)) + v
    codes = jax.random.randint(jax.random.fold_in(key, 1), (32, 2), 0,
                               4).astype(jnp.uint8)
    idx = FlatADC.build(codes, C, topk=5, backend="jnp")
    return Artifacts(config=ICQConfig(index=IndexConfig(kind="flat")),
                     index=idx)


def test_interrupted_save_keeps_previous_loadable(tmp_path, monkeypatch):
    from repro.api import Artifacts
    path = str(tmp_path / "art")
    _small_artifacts(tmp_path, 0.0).save(path)
    before = np.asarray(Artifacts.load(path).index.C)

    import json as json_mod
    def boom(*a, **k):
        raise RuntimeError("simulated crash mid-save")
    monkeypatch.setattr(json_mod, "dump", boom)
    with pytest.raises(RuntimeError):
        _small_artifacts(tmp_path, 1.0).save(path)
    monkeypatch.undo()

    after = Artifacts.load(path, verify_checksums=True)
    np.testing.assert_array_equal(np.asarray(after.index.C), before)


def test_old_backup_recovered_on_load(tmp_path):
    from repro.api import Artifacts
    path = str(tmp_path / "art")
    _small_artifacts(tmp_path, 2.0).save(path)
    before = np.asarray(Artifacts.load(path).index.C)
    # a crash between the two swap renames leaves only <path>.old
    os.rename(path, path + ".old")
    art = Artifacts.load(path, verify_checksums=True)
    np.testing.assert_array_equal(np.asarray(art.index.C), before)


def test_corrupted_tensor_rejected_by_name(tmp_path):
    from repro.api import ArtifactError, Artifacts
    path = str(tmp_path / "art")
    _small_artifacts(tmp_path).save(path)
    npz = os.path.join(path, "arrays.npz")
    arrs = dict(np.load(npz))
    inj = FaultInjector(seed=3)
    arrs["index/C"] = inj.corrupt_array(arrs["index/C"])
    np.savez(npz.removesuffix(".npz"), **arrs)   # same shapes/dtypes
    assert os.path.exists(npz)
    Artifacts.load(path)                          # lazy load still fine
    with pytest.raises(ArtifactError, match="index/C"):
        Artifacts.load(path, verify_checksums=True)


def test_truncated_npz_expected_vs_found(tmp_path):
    from repro.api import ArtifactError, Artifacts
    path = str(tmp_path / "art")
    _small_artifacts(tmp_path).save(path)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(ArtifactError, match="expected .* bytes, found"):
        Artifacts.load(path)


# ------------------------------------------------- supervised training ----

def _train_data():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((384, 16)).astype(np.float32)
    ys = rng.integers(0, 8, size=(384,))
    return xs, ys


@pytest.fixture(scope="module")
def fitted_plain():
    from repro.configs.base import ICQConfig
    from repro.trainer import fit
    xs, ys = _train_data()
    cfg = ICQConfig(d=8, num_codebooks=4, codebook_size=8, num_fast=2)
    return fit(jax.random.PRNGKey(5), xs, ys, cfg, mode="icq", epochs=3,
               batch_size=128)


def _fit_supervised(ckpt_dir, fault_hook=None):
    from repro.configs.base import ICQConfig
    from repro.trainer import fit
    xs, ys = _train_data()
    cfg = ICQConfig(d=8, num_codebooks=4, codebook_size=8, num_fast=2)
    return fit(jax.random.PRNGKey(5), xs, ys, cfg, mode="icq", epochs=3,
               batch_size=128, ckpt_dir=ckpt_dir, fault_hook=fault_hook)


def test_supervised_fit_matches_plain_bitwise(tmp_path, fitted_plain):
    m = _fit_supervised(str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(fitted_plain.C),
                                  np.asarray(m.C))
    np.testing.assert_array_equal(np.asarray(fitted_plain.codes),
                                  np.asarray(m.codes))


def test_fault_resume_bitwise_codebooks(tmp_path, fitted_plain):
    """A node-loss fault mid-fit restarts from the checkpoint; the
    resumed run's final codebooks are bitwise the uninterrupted ones."""
    crashed = {"done": False}
    def hook(ep):
        if ep == 2 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFault("node loss")
    m = _fit_supervised(str(tmp_path / "ck"), fault_hook=hook)
    assert crashed["done"]
    np.testing.assert_array_equal(np.asarray(fitted_plain.C),
                                  np.asarray(m.C))
    np.testing.assert_array_equal(np.asarray(fitted_plain.codes),
                                  np.asarray(m.codes))


_KILL_RESUME_SCRIPT = textwrap.dedent("""
    import os, signal, sys
    import numpy as np, jax
    from repro.configs.base import ICQConfig
    from repro.trainer import fit

    ckpt_dir, out, kill_at = sys.argv[1], sys.argv[2], sys.argv[3]
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((384, 16)).astype(np.float32)
    ys = rng.integers(0, 8, size=(384,))
    cfg = ICQConfig(d=8, num_codebooks=4, codebook_size=8, num_fast=2)

    hook = None
    if kill_at != "none":
        def hook(ep, _k=int(kill_at)):
            if ep == _k:
                os.kill(os.getpid(), signal.SIGKILL)   # hard node loss
    m = fit(jax.random.PRNGKey(5), xs, ys, cfg, mode="icq", epochs=4,
            batch_size=128, ckpt_dir=ckpt_dir, fault_hook=hook)
    np.savez(out, C=np.asarray(m.C), codes=np.asarray(m.codes))
    print("FIT_DONE")
""")


def test_sigkill_and_resume_subprocess(tmp_path):
    """SIGKILL mid-fit, then re-invoke with the same key and data: the
    resumed process's final codebooks are bitwise-identical to an
    uninterrupted run (the CI chaos job's smoke)."""
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    ck_a, ck_b = str(tmp_path / "a"), str(tmp_path / "b")
    out_ref, out_res = str(tmp_path / "ref.npz"), str(tmp_path / "res.npz")

    def run(ck, out, kill_at):
        return subprocess.run(
            [sys.executable, "-c", _KILL_RESUME_SCRIPT, ck, out, kill_at],
            capture_output=True, text=True, timeout=600, env=env)

    ref = run(ck_a, out_ref, "none")
    assert ref.returncode == 0, ref.stdout + ref.stderr

    killed = run(ck_b, out_res, "3")
    assert killed.returncode == -signal.SIGKILL
    assert not os.path.exists(out_res)           # it really died mid-fit
    resumed = run(ck_b, out_res, "none")
    assert resumed.returncode == 0, resumed.stdout + resumed.stderr

    a, b = np.load(out_ref), np.load(out_res)
    np.testing.assert_array_equal(a["C"], b["C"])
    np.testing.assert_array_equal(a["codes"], b["codes"])
