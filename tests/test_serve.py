"""The async serving engine (docs/serving.md): coalescer state machine
(pure, fake-clock driven), serving-loop bitwise parity vs direct calls
across all three index kinds, multi-tenant routing + spec validation,
queue/batching metadata, degraded-not-broken under injected faults, and
seeded load-generator determinism.
"""
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.api import build_ann_engine, icq_session, ICQConfig
from repro.core import codebooks as cb
from repro.data.synthetic import make_synthetic_index
from repro.resilience import FaultInjector, FaultSpec, ResultMeta, \
    SearchBudget
from repro.serve import (Coalescer, PendingRequest, ServeError, ServingLoop,
                         Tenant, make_workload, parse_tenant_specs,
                         poisson_arrivals, run_open_loop, summarize)

D, TOPK = 16, 10


def _req(nq, t=0.0, tenant="t"):
    q = np.arange(nq * D, dtype=np.float32).reshape(nq, D)
    return PendingRequest(tenant, q, None, None, t, Future())


# --------------------------------------------------------------- engines --
@pytest.fixture(scope="module")
def engines():
    """One small engine per index kind (jnp backend)."""
    key = jax.random.PRNGKey(0)
    codes, C, structure = make_synthetic_index(key, 2000, d=D, K=8, m=32,
                                               num_fast=2)
    out = {
        "flat": build_ann_engine(codes, C, structure, topk=TOPK,
                                 backend="jnp", index="flat"),
        "two-step": build_ann_engine(codes, C, structure, topk=TOPK,
                                     backend="jnp"),
        "ivf": build_ann_engine(codes, C, structure, topk=TOPK,
                                backend="jnp", index="ivf",
                                emb_db=cb.decode(C, codes), n_lists=16,
                                n_probe=4, key=jax.random.fold_in(key, 1)),
    }
    return out


# ------------------------------------------------- coalescer state machine --
class TestCoalescer:
    def test_flush_on_full_tile_fires_immediately(self):
        c = Coalescer(tile=4, window_s=10.0)   # window can't be the trigger
        assert c.submit(_req(3), now=0.0) == []
        flushes = c.submit(_req(1), now=0.1)
        assert len(flushes) == 1
        assert flushes[0].reason == "full"
        assert flushes[0].rows == flushes[0].tile == 4
        assert c.pending_rows == 0

    def test_flush_on_window_expiry(self):
        c = Coalescer(tile=8, window_s=0.5)
        c.submit(_req(3), now=1.0)
        assert c.next_deadline() == pytest.approx(1.5)
        assert c.poll(now=1.49) == []          # window not yet expired
        flushes = c.poll(now=1.5)
        assert len(flushes) == 1
        assert flushes[0].reason == "window"
        assert flushes[0].rows == 3 and flushes[0].tile == 8
        assert flushes[0].fill == pytest.approx(3 / 8)
        assert c.poll(now=2.0) == [] and c.next_deadline() is None

    def test_oversize_burst_splits_across_tiles(self):
        c = Coalescer(tile=4, window_s=1.0)
        req = _req(10)
        flushes = c.submit(req, now=0.0)
        assert [f.reason for f in flushes] == ["full", "full"]
        assert [f.rows for f in flushes] == [4, 4]
        # the remainder waits for more rows or the window
        assert c.pending_rows == 2
        spans = [(s.req_start, s.rows) for f in flushes for s in f.slices]
        assert spans == [(0, 4), (4, 4)]
        tail = c.flush_all()
        assert [f.rows for f in tail] == [2]
        assert tail[0].slices[0].req_start == 8

    def test_fifo_packing_and_row_routing(self):
        c = Coalescer(tile=6, window_s=1.0)
        a, b, d = _req(2, t=0.0), _req(3, t=0.1), _req(4, t=0.2)
        c.submit(a, now=0.0)
        c.submit(b, now=0.1)
        flushes = c.submit(d, now=0.2)         # 9 rows pending -> one tile
        assert len(flushes) == 1
        f = flushes[0]
        # FIFO: a's 2 rows, b's 3, then d's first row fills the tile
        assert [(s.request.rid, s.req_start, s.batch_start, s.rows)
                for s in f.slices] == [
            (a.rid, 0, 0, 2), (b.rid, 0, 2, 3), (d.rid, 0, 5, 1)]
        # the concatenated tile rows are exactly the requests' rows
        np.testing.assert_array_equal(
            f.queries(),
            np.concatenate([a.queries, b.queries, d.queries[:1]]))
        # window re-arms from the split survivor's submit time
        assert c.next_deadline() == pytest.approx(0.2 + 1.0)

    def test_deliver_and_assemble_reorders_split_parts(self):
        req = _req(5)
        ids_a = np.arange(10).reshape(2, 5)
        ids_b = np.arange(15).reshape(3, 5) + 100
        # parts can complete out of order; assemble sorts by req_start
        assert not req.deliver(2, ids_b, ids_b * 0.5, "resB", fill=1.0)
        assert req.deliver(0, ids_a, ids_a * 0.5, "resA", fill=0.5)
        ids, dists, last, fill = req.assemble()
        np.testing.assert_array_equal(ids, np.concatenate([ids_a, ids_b]))
        assert last == "resB"                  # last part by request row
        assert fill == pytest.approx((2 * 0.5 + 3 * 1.0) / 5)

    def test_flush_all_drains_everything(self):
        c = Coalescer(tile=4, window_s=9.0)
        c.submit(_req(3), now=0.0)
        c.submit(_req(3), now=0.0)             # -> one full flush emitted
        drained = c.flush_all()
        assert sum(f.rows for f in drained) == 2
        assert all(f.reason == "drain" for f in drained)
        assert c.pending_rows == 0 and c.flush_all() == []

    def test_invalid_knobs_rejected(self):
        with pytest.raises(ServeError, match="tile"):
            Coalescer(tile=0, window_s=1.0)
        with pytest.raises(ServeError, match="window"):
            Coalescer(tile=4, window_s=-0.1)


# ------------------------------------------------------- loop bitwise parity --
class TestServingLoopParity:
    @pytest.mark.parametrize("kind", ["flat", "two-step", "ivf"])
    def test_coalesced_bitwise_identical_to_direct(self, engines, kind):
        """The hard invariant: scheduling never changes math — ids AND
        distances of a coalesced response equal a direct search on the
        same rows, for every index kind, across coalesced/split/padded
        flushes."""
        eng = engines[kind]
        rng = np.random.default_rng(3)
        reqs = [rng.standard_normal((nq, D)).astype(np.float32)
                for nq in (1, 2, 4, 1, 5, 3)]  # 5 > tile: split path
        with ServingLoop(Tenant(name="t", engine=eng), window_ms=1.0,
                         tile=4) as loop:
            loop.warm()
            futs = [loop.submit(q) for q in reqs]
            results = [f.result(timeout=60) for f in futs]
        for q, res in zip(reqs, results):
            ref = eng.search(q)
            np.testing.assert_array_equal(np.asarray(res.indices),
                                          np.asarray(ref.indices))
            np.testing.assert_array_equal(np.asarray(res.distances),
                                          np.asarray(ref.distances))

    def test_searcher_tenant_parity_and_meta(self, rng, key):
        """A Searcher-backed tenant (embed model in front) serves
        bitwise what searcher.search returns, and only the loop's
        results carry queue_ms/batch_fill."""
        X = rng.standard_normal((256, 32)).astype(np.float32)
        sess = icq_session(ICQConfig().with_overrides(
            {"train.d": 16, "train.num_codebooks": 4,
             "train.codebook_size": 16, "train.epochs": 1}))
        sess.fit(X, key=key)
        searcher = sess.index(
            rng.standard_normal((400, 32)).astype(np.float32))
        q = rng.standard_normal((3, 32)).astype(np.float32)
        with ServingLoop(Tenant.from_searcher("s", searcher),
                         window_ms=1.0, tile=4) as loop:
            res = loop.search(q, k=5)
        ref = searcher.search(q, 5)
        np.testing.assert_array_equal(np.asarray(res.indices),
                                      np.asarray(ref.indices))
        np.testing.assert_array_equal(np.asarray(res.distances),
                                      np.asarray(ref.distances))
        # loop results carry the serving metadata; direct ones don't
        assert res.meta.queue_ms is not None and res.meta.queue_ms >= 0
        assert res.meta.batch_fill == pytest.approx(3 / 4)
        assert ref.meta.queue_ms is None and ref.meta.batch_fill is None

    def test_offline_meta_defaults_are_none(self):
        m = ResultMeta()
        assert m.queue_ms is None and m.batch_fill is None


# --------------------------------------------------------- loop lifecycle --
class TestServingLoopLifecycle:
    def test_close_drains_pending_requests(self, engines):
        """Clean shutdown: requests still queued (window not yet
        expired) are served, not dropped."""
        loop = ServingLoop(Tenant(name="t", engine=engines["two-step"]),
                           window_ms=10_000.0, tile=32).start()
        q = np.zeros((2, D), np.float32)
        fut = loop.submit(q)                   # far below the tile; only
        loop.close()                           # the drain can flush it
        res = fut.result(timeout=5)
        assert np.asarray(res.indices).shape == (2, TOPK)
        with pytest.raises(ServeError, match="closed"):
            loop.submit(q)
        loop.close()                           # idempotent

    def test_never_started_close_serves_inline(self, engines):
        loop = ServingLoop(Tenant(name="t", engine=engines["two-step"]),
                           window_ms=10_000.0, tile=8)
        fut = loop.submit(np.zeros((1, D), np.float32))
        loop.close()
        assert np.asarray(fut.result(timeout=5).indices).shape == (1, TOPK)

    def test_max_queue_backpressure(self, engines):
        loop = ServingLoop(Tenant(name="t", engine=engines["two-step"]),
                           window_ms=10_000.0, tile=32, max_queue=4)
        for _ in range(4):
            loop.submit(np.zeros((1, D), np.float32))
        with pytest.raises(ServeError, match="queue full"):
            loop.submit(np.zeros((1, D), np.float32))
        loop.close()

    def test_submit_validation(self, engines):
        t1 = Tenant(name="a", engine=engines["flat"])
        t2 = Tenant(name="b", engine=engines["two-step"])
        with ServingLoop([t1, t2], window_ms=1.0, tile=4) as loop:
            with pytest.raises(ServeError, match="pass "):
                loop.submit(np.zeros((1, D), np.float32))  # ambiguous
            with pytest.raises(ServeError, match="unknown tenant"):
                loop.submit(np.zeros((1, D), np.float32), tenant="zzz")
            with pytest.raises(ServeError, match="d="):
                loop.submit(np.zeros((1, D + 1), np.float32), tenant="a")
            with pytest.raises(ServeError, match="shape"):
                loop.submit(np.zeros((1, 1, D), np.float32), tenant="a")


# ------------------------------------------------------------ multi-tenant --
class TestTenants:
    def test_parse_tenant_specs_conflicts(self, tmp_path):
        a, b = tmp_path / "a", tmp_path / "b"
        a.mkdir(), b.mkdir()
        assert parse_tenant_specs([f"x={a}", f"y={b}"]) == [
            ("x", str(a)), ("y", str(b))]
        with pytest.raises(ServeError, match="NAME=ARTIFACTS_DIR"):
            parse_tenant_specs(["noequals"])
        with pytest.raises(ServeError, match="duplicate tenant name"):
            parse_tenant_specs([f"x={a}", f"x={b}"])
        with pytest.raises(ServeError, match="both point at"):
            # same dir through a symlink-free alias still collides
            parse_tenant_specs([f"x={a}", f"y={tmp_path}/./a"])

    def test_tenant_name_validation(self, engines):
        with pytest.raises(ServeError, match="name"):
            Tenant(name="", engine=engines["flat"])
        with pytest.raises(ServeError, match="name"):
            Tenant(name="a=b", engine=engines["flat"])
        with pytest.raises(ServeError, match="duplicate"):
            ServingLoop([Tenant(name="a", engine=engines["flat"]),
                         Tenant(name="a", engine=engines["two-step"])])

    def test_per_tenant_routing_is_isolated(self, engines):
        """Requests coalesce per lane: each tenant's rows only ever hit
        its own engine."""
        t1 = Tenant(name="flat", engine=engines["flat"])
        t2 = Tenant(name="ivf", engine=engines["ivf"])
        rng = np.random.default_rng(7)
        q = rng.standard_normal((2, D)).astype(np.float32)
        with ServingLoop([t1, t2], window_ms=1.0, tile=4) as loop:
            r1 = loop.search(q, tenant="flat")
            r2 = loop.search(q, tenant="ivf")
        np.testing.assert_array_equal(
            np.asarray(r1.indices),
            np.asarray(engines["flat"].search(q).indices))
        np.testing.assert_array_equal(
            np.asarray(r2.indices),
            np.asarray(engines["ivf"].search(q).indices))


# ----------------------------------------------------- degraded, not broken --
class TestDegradedServing:
    def test_fault_delay_under_deadline_degrades_without_errors(self):
        """Injected stage delays + a tight per-tenant deadline: the
        ladder serves degraded responses; no request errors out."""
        key = jax.random.PRNGKey(1)
        codes, C, structure = make_synthetic_index(key, 2000, d=D, K=8,
                                                   m=32, num_fast=2)
        inj = FaultInjector(seed=0, spec=FaultSpec(
            p_delay=0.9, delay_ms=15.0, targets=("engine.search",)))
        eng = build_ann_engine(codes, C, structure, topk=TOPK,
                               backend="jnp", fault_injector=inj)
        tenant = Tenant(name="t", engine=eng,
                        budget=SearchBudget(deadline_ms=1.0))
        rng = np.random.default_rng(5)
        with inj.installed():
            with ServingLoop(tenant, window_ms=0.5, tile=4) as loop:
                futs = [loop.submit(
                    rng.standard_normal((1, D)).astype(np.float32))
                    for _ in range(12)]
                results = [f.result(timeout=60) for f in futs]
        assert len(results) == 12              # nothing raised
        assert all(r.meta is not None for r in results)
        assert any(r.meta.degraded for r in results)
        # the tenant default budget reached the engine: deadlines stamped
        assert all(r.meta.deadline_ms == 1.0 for r in results)


# ---------------------------------------------------------------- loadgen --
class TestLoadgen:
    def test_poisson_arrivals_seeded_and_bounded(self):
        a = poisson_arrivals(100.0, 2.0, rng=np.random.default_rng(0))
        b = poisson_arrivals(100.0, 2.0, rng=np.random.default_rng(0))
        np.testing.assert_array_equal(a, b)
        assert (a >= 0).all() and (a < 2.0).all()
        assert (np.diff(a) >= 0).all()
        # ~rate*duration arrivals, very loose tolerance
        assert 100 < len(a) < 320
        c = poisson_arrivals(100.0, 2.0, rng=np.random.default_rng(9))
        assert not np.array_equal(a, c)
        with pytest.raises(ValueError, match="rate_hz"):
            poisson_arrivals(0.0, 1.0, rng=np.random.default_rng(0))

    def test_make_workload_same_seed_identical(self):
        pools = {"b": np.ones((8, D), np.float32) * 2,
                 "a": np.ones((8, D), np.float32)}
        w1 = make_workload(pools, 80.0, 1.0, rng=np.random.default_rng(4))
        w2 = make_workload(pools, 80.0, 1.0, rng=np.random.default_rng(4))
        assert len(w1) == len(w2) > 0
        for s1, s2 in zip(w1, w2):
            assert s1.t_arrival == s2.t_arrival
            assert s1.tenant == s2.tenant
            np.testing.assert_array_equal(s1.queries, s2.queries)
        assert {s.tenant for s in w1} <= {"a", "b"}

    def test_open_loop_records_and_summary(self, engines):
        pools = {"t": np.asarray(
            np.random.default_rng(1).standard_normal((8, D)), np.float32)}
        work = make_workload(pools, 200.0, 0.2,
                             rng=np.random.default_rng(2))
        with ServingLoop(Tenant(name="t", engine=engines["two-step"]),
                         window_ms=1.0, tile=4) as loop:
            loop.warm()
            t0 = time.time()
            recs = run_open_loop(loop, work)
            wall = time.time() - t0
        s = summarize(recs, wall_s=wall)
        assert s["requests"] == len(work)
        assert np.isfinite(s["p50_ms"]) and np.isfinite(s["p99_ms"])
        assert s["p50_ms"] <= s["p99_ms"]
        assert s["qps"] > 0 and s["rows_per_s"] >= s["qps"]
        assert 0 < s["mean_batch_fill"] <= 1.0
        assert s["mean_queue_ms"] >= 0
