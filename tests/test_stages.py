"""Stage protocol + overlapped crude/refine pipeline (DESIGN.md §13).

Two parity layers:

* **Composed stages == monolithic engines** — the ``(crude_fn,
  refine_fn)`` phase pairs (``flat.two_step_phase_fns`` /
  ``ivf.ivf_phase_fns``) composed by hand reproduce the fused search
  entry points *bitwise*, over random geometries (non-divisible tiles,
  odd-K nibble codes, ``K_fast`` at both edges), both backends, all
  three index kinds, ``code_bits`` in {8, 4} and ``lut_dtype`` in
  {f32, int8}.

* **Pipelined == jitted sequential** — the tile executor
  (``index/pipelined.py``) returns bitwise-identical ids + distances
  to ``jax.jit(index.search)`` — the exact program ``AnnEngine``
  serves.  The *eager* sequential path may differ from any jitted
  program by reassociation ulps on some shapes (XLA folds closed-over
  constants differently than eager dispatch), so the eager comparison
  pins ids bitwise and distances to f32 tolerance; see the
  ``index/pipelined.py`` module docstring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebooks as cb
from repro.core import icq as icq_mod
from repro.core.encode import pack_nibbles
from repro.index import flat as flat_mod
from repro.index import ivf as ivf_mod
from repro.index import make_index, two_step_search
from repro.index.pipelined import (PIPELINE_MODES, maybe_pipelined,
                                   plan_for, resolve_pipeline,
                                   resolve_tile)

KINDS = ("flat", "two-step", "ivf")


def _problem(key, n, nq, K=6, m=16, kf=3, d=16, sigma=0.6):
    """Random packed problem (codebook_size <= 16 so the same codes
    serve both code_bits layouts)."""
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


def _build(kind, codes, C, st, *, key, backend, code_bits=8,
           lut_dtype="f32", **opts):
    cds = pack_nibbles(codes, C.shape[0]) if code_bits == 4 else codes
    kw = dict(topk=10, backend=backend, code_bits=code_bits,
              lut_dtype=lut_dtype, **opts)
    if backend == "pallas":
        kw["interpret"] = True
    if kind == "ivf":
        kw.update(emb_db=cb.decode(C, codes), n_lists=16, n_probe=4,
                  key=jax.random.fold_in(key, 7))
    return make_index(kind, cds, C, st, **kw)


def _bitwise(a, b):
    return (bool(jnp.array_equal(a.indices, b.indices))
            and bool(jnp.array_equal(a.distances, b.distances)))


# ------------------------------- composed stages vs monolithic ----------

@pytest.mark.parametrize("seed", range(6))
def test_flat_phase_composition_matches_monolithic(key, seed):
    """Property-style: crude→threshold→refine composed by hand from the
    phase pair == ``two_step_search``, bitwise, over random geometry —
    n not divisible by the block sizes, odd K (nibble sentinel), kf at
    both edges, both code_bits, both lut_dtypes."""
    rng = np.random.default_rng(seed)
    K = int(rng.choice([3, 5, 6, 7]))
    kf = int(rng.choice([1, K - 1]))
    n = int(rng.integers(257, 900))
    nq = int(rng.integers(3, 40))
    code_bits = int(rng.choice([8, 4]))
    lut_dtype = str(rng.choice(["f32", "int8"]))
    k2 = jax.random.fold_in(key, seed)
    q, codes, C, st = _problem(k2, n, nq, K=K, kf=kf)
    cds = pack_nibbles(codes, K) if code_bits == 4 else codes

    ref = two_step_search(q, cds, C, st, 9, backend="jnp",
                          lut_dtype=lut_dtype, code_bits=code_bits)
    quantized = lut_dtype == "int8"
    env = flat_mod.two_step_phase_env(cds, C, st, backend="jnp",
                                      code_bits=code_bits)
    crude_fn, refine_fn = flat_mod.two_step_phase_fns(
        topk=9, backend="jnp", quantized=quantized, code_bits=code_bits)
    idx, dist, pf = refine_fn(crude_fn(q, env), env)
    assert bool(jnp.array_equal(idx, ref.indices))
    assert bool(jnp.array_equal(dist, ref.distances))
    assert bool(jnp.array_equal(jnp.mean(pf), ref.pass_rate))


@pytest.mark.parametrize("code_bits,lut_dtype",
                         [(8, "f32"), (8, "int8"), (4, "int8")])
def test_flat_phase_composition_pallas(key, code_bits, lut_dtype):
    """Same composition contract through the fused kernels (interpret
    mode): the phase pair wraps ``batched_crude_topk`` /
    ``batched_refine_topk`` and must reproduce the monolithic pallas
    path bitwise on non-divisible shapes."""
    q, codes, C, st = _problem(jax.random.fold_in(key, 11), 700, 9, K=5,
                               kf=2)
    cds = pack_nibbles(codes, 5) if code_bits == 4 else codes
    ref = two_step_search(q, cds, C, st, 9, backend="pallas",
                          interpret=True, lut_dtype=lut_dtype,
                          code_bits=code_bits)
    env = flat_mod.two_step_phase_env(cds, C, st, backend="pallas",
                                      code_bits=code_bits)
    crude_fn, refine_fn = flat_mod.two_step_phase_fns(
        topk=9, backend="pallas", interpret=True,
        quantized=lut_dtype == "int8", code_bits=code_bits)
    idx, dist, pf = refine_fn(crude_fn(q, env), env)
    assert bool(jnp.array_equal(idx, ref.indices))
    assert bool(jnp.array_equal(dist, ref.distances))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ivf_phase_composition_matches_monolithic(key, backend):
    q, codes, C, st = _problem(jax.random.fold_in(key, 13), 900, 11,
                               K=6, kf=3)
    ivf = ivf_mod.build_ivf(jax.random.fold_in(key, 7),
                            cb.decode(C, codes), 16)
    slab = ivf_mod.ivf_list_codes(ivf, codes)
    kw = dict(interpret=True) if backend == "pallas" else {}
    ref = ivf_mod.ivf_two_step_search(q, codes, C, st, ivf, 9, 4,
                                      backend=backend, list_codes=slab,
                                      **kw)
    env = ivf_mod.ivf_phase_env(codes, C, st, ivf, list_codes=slab)
    crude_fn, refine_fn = ivf_mod.ivf_phase_fns(
        topk=9, n_probe=4, backend=backend, quantized=False, code_bits=8,
        **kw)
    idx, dist, _, _ = refine_fn(crude_fn(q, env), env)
    assert bool(jnp.array_equal(idx, ref.indices))
    assert bool(jnp.array_equal(dist, ref.distances))


# ------------------------------- pipelined vs sequential ----------------

@pytest.mark.parametrize("lut_dtype", ["f32", "int8"])
@pytest.mark.parametrize("code_bits", [8, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("kind", KINDS)
def test_pipelined_bitwise_vs_jitted_sequential(key, kind, backend,
                                                code_bits, lut_dtype):
    """The full matrix: 3 kinds x {jnp, pallas} x code_bits {8, 4} x
    lut_dtype {f32, int8}.  Pipelined search == ``jax.jit(seq.search)``
    bitwise (ids + distances); eager sequential agrees on ids bitwise
    and on distances to f32 tolerance."""
    k2 = jax.random.fold_in(key, 17)
    q, codes, C, st = _problem(k2, 2000, 70, K=6, kf=3)
    mk = lambda **o: _build(kind, codes, C, st, key=k2, backend=backend,
                            code_bits=code_bits, lut_dtype=lut_dtype, **o)
    i0 = mk()
    i1 = mk(pipeline="tiles", pipeline_tile=32)      # 70 = 2*32 + 6
    seq = jax.jit(lambda qq: i0.search(qq, 10))
    r_jit, r_pipe, r_eager = seq(q), i1.search(q, 10), i0.search(q, 10)
    assert _bitwise(r_jit, r_pipe)
    assert bool(jnp.array_equal(r_eager.indices, r_pipe.indices))
    fin = jnp.isfinite(r_eager.distances)
    assert bool(jnp.allclose(jnp.where(fin, r_eager.distances, 0.0),
                             jnp.where(fin, r_pipe.distances, 0.0),
                             rtol=1e-5, atol=1e-5))


@pytest.mark.parametrize("seed", range(6))
def test_pipelined_random_shapes(key, seed):
    """Property-style executor shapes: random n/nq/tile (nq not a tile
    multiple, tiles smaller and larger than nq), odd K nibble codes,
    kf at the edges."""
    rng = np.random.default_rng(100 + seed)
    K = int(rng.choice([3, 5, 7]))
    kf = int(rng.choice([1, K - 1]))
    n = int(rng.integers(300, 1500))
    nq = int(rng.integers(3, 97))
    tile = int(rng.choice([5, 8, 17, 32]))
    code_bits = int(rng.choice([8, 4]))
    lut_dtype = str(rng.choice(["f32", "int8"]))
    k2 = jax.random.fold_in(key, 1000 + seed)
    q, codes, C, st = _problem(k2, n, nq, K=K, kf=kf)
    i0 = _build("two-step", codes, C, st, key=k2, backend="jnp",
                code_bits=code_bits, lut_dtype=lut_dtype)
    i1 = dataclasses.replace(i0, pipeline="tiles", pipeline_tile=tile)
    r0 = jax.jit(lambda qq: i0.search(qq, 7))(q)
    assert _bitwise(r0, i1.search(q, 7))


def test_pipelined_filter_and_refine_cap(key):
    """The jnp-only extras thread through the executor: a metadata
    filter predicate (traced operand, like the engine's jit) and the
    refine_cap compacted path."""
    k2 = jax.random.fold_in(key, 19)
    q, codes, C, st = _problem(k2, 1200, 50)
    pred = np.zeros(1200, bool)
    pred[::3] = True
    for kind in ("two-step", "ivf"):
        i0 = _build(kind, codes, C, st, key=k2, backend="jnp")
        i1 = dataclasses.replace(i0, pipeline="tiles", pipeline_tile=16)
        r0 = jax.jit(lambda qq, f: i0.search(qq, 10, filter=f))(q, pred)
        assert _bitwise(r0, i1.search(q, 10, filter=pred))
    i0 = _build("two-step", codes, C, st, key=k2, backend="jnp",
                refine_cap=64)
    i1 = dataclasses.replace(i0, pipeline="tiles", pipeline_tile=16)
    r0 = jax.jit(lambda qq: i0.search(qq, 10))(q)
    assert _bitwise(r0, i1.search(q, 10))


def test_pipelined_crude_rung_and_probe_override(key):
    """The resilience ladder composes with the pipeline: the degraded
    crude-only rung drops the refine stage (single-phase tile loop) and
    the IVF per-call ``n_probe`` override gets its own plan."""
    k2 = jax.random.fold_in(key, 23)
    q, codes, C, st = _problem(k2, 1200, 50)
    for kind in ("two-step", "ivf"):
        i0 = _build(kind, codes, C, st, key=k2, backend="jnp")
        i1 = dataclasses.replace(i0, pipeline="tiles", pipeline_tile=16)
        r0 = jax.jit(lambda qq: i0.search_crude(qq, 10))(q)
        assert _bitwise(r0, i1.search_crude(q, 10))
    i0 = _build("ivf", codes, C, st, key=k2, backend="jnp")
    i1 = dataclasses.replace(i0, pipeline="tiles", pipeline_tile=16)
    r0 = jax.jit(lambda qq: i0.search_crude(qq, 10, n_probe=2))(q)
    assert _bitwise(r0, i1.search_crude(q, 10, n_probe=2))


def test_auto_mode_and_plan_cache(key):
    """``auto`` declines single-tile batches (falls through to the
    sequential path) and engages beyond one tile; plans are cached per
    index instance and ``add`` starts a fresh instance with no stale
    closures."""
    k2 = jax.random.fold_in(key, 29)
    q, codes, C, st = _problem(k2, 800, 40)
    i1 = _build("two-step", codes, C, st, key=k2, backend="jnp",
                pipeline="auto", pipeline_tile=32)
    # nq <= tile: maybe_pipelined declines
    assert maybe_pipelined(i1, q[:16], 10) is None
    i0 = _build("two-step", codes, C, st, key=k2, backend="jnp")
    r0 = jax.jit(lambda qq: i0.search(qq, 10))(q)
    assert _bitwise(r0, i1.search(q, 10))
    # the plan closed over this instance's buffers — cached on it
    plans = i1.__dict__["_pipeline_plans"]
    assert len(plans) == 1
    i1.search(q, 10)
    assert len(plans) == 1
    assert plan_for(i1, 10) is next(iter(plans.values()))
    # add() returns a fresh instance: no inherited plan cache, and the
    # new plan sees the grown database
    new_vecs = cb.decode(C, codes[:37])
    i2 = i1.add(new_vecs)
    assert "_pipeline_plans" not in i2.__dict__
    i0b = i0.add(new_vecs)
    r0b = jax.jit(lambda qq: i0b.search(qq, 10))(q)
    assert _bitwise(r0b, i2.search(q, 10))


def test_resolve_helpers_and_validation():
    assert PIPELINE_MODES == ("off", "tiles", "auto")
    for mode in PIPELINE_MODES:
        assert resolve_pipeline(mode) == mode
    with pytest.raises(ValueError):
        resolve_pipeline("overlap")
    assert resolve_tile(None, "jnp", 64) == 16
    assert resolve_tile(None, "pallas", 64) == 64
    assert resolve_tile(8, "jnp", 64) == 8
    with pytest.raises(ValueError):
        resolve_tile(0, "jnp", 64)


def test_sharded_clone_serves_pipeline_off(key):
    """Sharding a pipelined index yields a working non-pipelined clone
    (the shard_map body is one fused SPMD program — no host-level stage
    boundary to overlap)."""
    k2 = jax.random.fold_in(key, 31)
    q, codes, C, st = _problem(k2, 800, 40)
    i1 = _build("two-step", codes, C, st, key=k2, backend="jnp",
                pipeline="tiles", pipeline_tile=16)
    mesh = jax.make_mesh((1,), ("data",))
    sh = i1.shard(mesh)
    assert sh.pipeline == "off"
    r0 = jax.jit(lambda qq: i1.search(qq, 10))(q)
    assert bool(jnp.array_equal(r0.indices, sh.search(q, 10).indices))


def test_tune_grid_offers_pipeline():
    """session.tune's coarse grid includes the pipeline candidate for
    every index kind (a pure scheduling knob: one candidate at the
    default operating point)."""
    from repro.api import ICQConfig
    from repro.api.session import ICQSession

    for kind in ("flat", "two-step", "ivf"):
        cfg = ICQConfig.from_dict({"schema_version": 1,
                                   "index": {"kind": kind}})
        sess = ICQSession.__new__(ICQSession)
        sess.config = cfg
        assert {"serve.pipeline": "tiles"} in sess._tune_grid()
