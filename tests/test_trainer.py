"""Trainer layer (DESIGN.md §9): tiled ICM encoding engine invariants
(objective monotone, jnp==pallas==oracle code parity, warm start,
chunk invariance), the padded-chunk database encoder, the scan-compiled
epoch driver (key threading, host-loop equivalence), the Quantizer
protocol, data-parallel training (subprocess under forced host devices
— the in-process suite must keep seeing 1 device, see conftest), and
the uint16 packed-codes regression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ICQConfig
from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core.icq import ICQStructure
from repro.index import adc_search, two_step_search
from repro.kernels.ref import icm_encode_gram
from repro.trainer import (Quantizer, encode_database, epoch_batches, fit,
                           make_quantizer)


@pytest.fixture(scope="module")
def icm_problem(key):
    # non-divisible n (prime-ish) to exercise pad/slice paths everywhere
    x = jax.random.normal(key, (517, 16)) * jnp.linspace(0.2, 3.0, 16)
    C = cb.init_residual(key, x, 4, 16, iters=5)
    return x, C


# ------------------------------------------------------- encoding engine ----

def test_icm_objective_non_increasing_per_sweep(icm_problem):
    x, C = icm_problem
    codes0 = enc.encode_pq(x, C)
    errs = [float(cb.quantization_mse(x, C, codes0))]
    for iters in (1, 2, 3):
        codes = enc.icm_encode(x, C, iters, backend="jnp")
        errs.append(float(cb.quantization_mse(x, C, codes)))
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-5


def test_icm_parity_jnp_pallas_oracle_non_divisible(icm_problem):
    x, C = icm_problem
    oracle = icm_encode_gram(x, C, 3)
    jnp_codes = enc.icm_encode(x, C, 3, backend="jnp")
    pl_codes = enc.icm_encode(x, C, 3, backend="pallas", block_n=128,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(jnp_codes), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(pl_codes), np.asarray(jnp_codes))


def test_icm_warm_start_equivalence(icm_problem):
    """Default warm start IS the PQ assignment: passing it explicitly
    must be a no-op, and a one-sweep hand-rolled warm start must match
    a later sweep of the default path."""
    x, C = icm_problem
    default = enc.icm_encode(x, C, 3, backend="jnp")
    explicit = enc.icm_encode(x, C, 3, init_codes=enc.encode_pq(x, C),
                              backend="jnp")
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    one = enc.icm_encode(x, C, 1, backend="jnp")
    resumed = enc.icm_encode(x, C, 2, init_codes=one, backend="jnp")
    np.testing.assert_array_equal(np.asarray(resumed), np.asarray(default))


def test_icm_point_chunk_invariance(icm_problem):
    """Encoding is per-point: chunked blocks (ragged tail included)
    assign identical codes."""
    x, C = icm_problem
    full = enc.icm_encode(x, C, 3, backend="jnp")
    chunked = enc.icm_encode(x, C, 3, backend="jnp", point_chunk=128)
    np.testing.assert_array_equal(np.asarray(chunked), np.asarray(full))


def test_icm_pq_codebooks_reduce_to_pq(key):
    """Orthogonal supports: interactions vanish, ICM == the independent
    PQ assignment (why Index.add can use one encode path)."""
    x = jax.random.normal(key, (200, 16))
    C = cb.init_pq(key, x, 4, 8)
    np.testing.assert_array_equal(
        np.asarray(enc.icm_encode(x, C, 3, backend="jnp")),
        np.asarray(enc.encode_pq(x, C)))


def test_encode_database_pads_ragged_chunk_single_compile(icm_problem):
    x, C = icm_problem
    direct = encode_database(x, C, mode="icm", icm_iters=2, chunk=517)
    ragged = encode_database(x, C, mode="icm", icm_iters=2, chunk=200)
    assert ragged.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(ragged), np.asarray(direct))


# ------------------------------------------------------------ epoch driver ----

@pytest.fixture(scope="module")
def train_data():
    from repro.data import make_table1_dataset
    xtr, ytr, _, _ = make_table1_dataset("dataset3")
    return np.asarray(xtr[:900]), np.asarray(ytr[:900])


def test_fit_threads_callers_key(train_data):
    """The seed fit hardcoded PRNGKey(0x5EED) for shuffling; runs must
    now be seeded by the caller's key."""
    xtr, ytr = train_data
    cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)
    kw = dict(mode="icq", epochs=2, batch_size=128)
    m1 = fit(jax.random.PRNGKey(1), xtr, ytr, cfg, **kw)
    m1b = fit(jax.random.PRNGKey(1), xtr, ytr, cfg, **kw)
    m2 = fit(jax.random.PRNGKey(2), xtr, ytr, cfg, **kw)
    np.testing.assert_array_equal(np.asarray(m1.codes), np.asarray(m1b.codes))
    assert not bool(jnp.all(m1.codes == m2.codes))


def test_fit_produces_usable_model(train_data):
    from repro.core import mean_average_precision
    xtr, ytr = train_data
    cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=4,
                batch_size=128)
    assert model.codes.shape == (900, 4) and model.codes.dtype == jnp.uint8
    r = adc_search(model.embed(xtr[:64]), model.codes, model.C, 10)
    mapv = float(mean_average_precision(r.indices, jnp.asarray(ytr),
                                        jnp.asarray(ytr[:64])))
    assert mapv > 0.5


def test_epoch_batches_permutes_and_drops_tail(train_data):
    xtr, ytr = train_data
    xb, yb = epoch_batches(jax.random.PRNGKey(3), xtr, ytr, 128)
    assert xb.shape == (7, 128, 64) and yb.shape == (7, 128)
    # a permutation, not a slice: rows are a subset of the originals
    flat = np.asarray(xb).reshape(-1, 64)
    assert not np.array_equal(flat, np.asarray(xtr[: 7 * 128]))


_DP_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ICQConfig
from repro.distributed.sharding import make_mesh_auto
from repro.trainer import fit
from repro.data import make_table1_dataset

xtr, ytr, _, _ = make_table1_dataset("dataset3")
xtr, ytr = np.asarray(xtr[:512]), np.asarray(ytr[:512])
cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)
mesh = make_mesh_auto((4,), ("data",))
kw = dict(mode="icq", epochs=2, batch_size=128)
m_dp = fit(jax.random.PRNGKey(1), xtr, ytr, cfg, mesh=mesh, **kw)
m_sd = fit(jax.random.PRNGKey(1), xtr, ytr, cfg, **kw)
agree = float(jnp.mean((m_dp.codes == m_sd.codes).astype(jnp.float32)))
assert agree > 0.98, agree           # identical up to float reassociation
assert jnp.allclose(m_dp.lam, m_sd.lam, rtol=1e-3, atol=1e-5)
print("DP_OK", agree)
"""


def test_data_parallel_fit_matches_single_device():
    """shard_map epoch driver under 4 forced host devices: pmean'd
    grads + global batch moments track the single-device run (exact up
    to float reassociation accumulating through SGD)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _DP_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr
    assert "DP_OK" in proc.stdout


# ------------------------------------------------------ quantizer protocol ----

def test_make_quantizer_registry(key, train_data):
    xtr, ytr = train_data
    cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)
    for kind in ("icq", "pq", "opq", "cq"):
        q = make_quantizer(kind, cfg)
        assert isinstance(q, Quantizer)
    with pytest.raises(ValueError, match="unknown quantizer"):
        make_quantizer("nope", cfg)
    # protocol round-trip on the cheapest unsupervised kind
    q = make_quantizer("pq", cfg)
    x16 = np.asarray(xtr[:300, :16])
    state = q.init(key, x16)
    state = q.step(state, x16)
    model = q.finalize(state, x16)
    assert model.codes.shape == (300, 4)
    np.testing.assert_array_equal(
        np.asarray(enc.unpack_codes(model.codes)),
        np.asarray(enc.encode_pq(jnp.asarray(x16), model.C)))


def test_joint_quantizer_steps_reduce_loss(key, train_data):
    xtr, ytr = train_data
    cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)
    q = make_quantizer("icq", cfg)
    state = q.init(key, xtr, ytr)
    losses = []
    for i in range(12):
        state = q.step(state, (xtr[:256], ytr[:256]))
        losses.append(float(state["last_metrics"]["total"]))
    assert losses[-1] < losses[0]


# ------------------------------------------------- uint16 packed codes ----

def test_uint16_codes_supported_end_to_end(key):
    """Regression (m > 256): pack_codes emits uint16 and every engine
    accepts it — codes widen to int32 at the LUT-sum / kernel boundary,
    so rankings are identical to unpacked int32 codes."""
    n, K, m, d = 400, 2, 512, 8
    codes_i32 = jax.random.randint(key, (n, K), 0, m)
    packed = enc.pack_codes(codes_i32, m)
    assert packed.dtype == jnp.uint16
    np.testing.assert_array_equal(np.asarray(enc.unpack_codes(packed)),
                                  np.asarray(codes_i32))
    C = jax.random.normal(jax.random.fold_in(key, 1), (K, m, d)) * 0.3
    st = ICQStructure(xi=jnp.ones((d,), bool),
                      fast_mask=jnp.asarray([True, False]),
                      sigma=jnp.asarray(1.0))
    q = jax.random.normal(jax.random.fold_in(key, 2), (5, d))
    for backend, kw in (("jnp", {}), ("pallas", dict(interpret=True))):
        r_packed = adc_search(q, packed, C, 7, backend=backend, **kw)
        r_i32 = adc_search(q, codes_i32, C, 7, backend=backend, **kw)
        np.testing.assert_array_equal(np.asarray(r_packed.indices),
                                      np.asarray(r_i32.indices))
        r2_packed = two_step_search(q, packed, C, st, 7, backend=backend,
                                    **kw)
        r2_i32 = two_step_search(q, codes_i32, C, st, 7, backend=backend,
                                 **kw)
        np.testing.assert_array_equal(np.asarray(r2_packed.indices),
                                      np.asarray(r2_i32.indices))
