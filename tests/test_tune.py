"""``ICQSession.tune`` acceptance tests (docs/api.md): the autotuner
must return a config that *actually* meets the recall target when
independently re-measured on a freshly built index, and the tuned
config must persist through Artifacts bitwise (config-hash identical
after a reload).

The workload is built so the quantizer has a real ceiling of 1.0: 24
well-separated bundles of 10 near-duplicate points each, queries at the
bundle centers — the top-10 of a query is exactly its bundle, which the
codebooks represent almost losslessly.  (Isotropic-noise Gaussians are
useless here: their quantization error floor caps exact-ground-truth
recall far below any sane target.)
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import eval as ev
from repro.api import ConfigError, ICQConfig, ICQSession, build_index


def _bundle_workload(seed=0, nb=24, per=10, d=16):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((nb, d)).astype(np.float32) * 4
    x = (np.repeat(centers, per, axis=0)
         + 0.05 * rng.standard_normal((nb * per, d))).astype(np.float32)
    y = np.repeat(np.arange(nb) % 4, per).astype(np.int32)
    q = (centers[rng.integers(0, nb, nb)]
         + 0.05 * rng.standard_normal((nb, d))).astype(np.float32)
    return x, y, q


def _cfg():
    return ICQConfig().with_overrides({
        "train.d": 16, "train.num_codebooks": 8,
        "train.codebook_size": 32, "train.num_fast": 2,
        "train.epochs": 2,
        "index.kind": "ivf", "index.n_lists": 4, "index.n_probe": 1,
        "serve.topk": 10, "serve.backend": "jnp"})


@pytest.fixture(scope="module")
def tuned_session():
    x, y, q = _bundle_workload()
    s = ICQSession(_cfg())
    s.fit(x, y)
    tuned = s.tune(queries=q, target_recall=0.8, k=10, repeats=1,
                   cache_dir=None)
    return s, tuned, x, q


def test_tune_meets_target_and_reports(tuned_session):
    s, tuned, _, _ = tuned_session
    rep = s.last_tune
    assert rep["met_target"] is True
    assert rep["target_recall"] == 0.8 and rep["k"] == 10
    assert rep["selected"]["recall"] >= 0.8
    assert rep["selected"] in rep["points"]
    # the report's frontier is a monotone recall-vs-qps curve
    assert ev.is_monotone_frontier(rep["frontier"])
    # apply=True adopted the winner on the session
    assert s.config.config_hash() == tuned.config_hash()
    nf = tuned.train.num_fast
    assert int(s.model.structure.fast_mask.sum()) == nf


def test_tuned_config_remeasures_at_target(tuned_session):
    """Independent re-measurement: build a fresh index from the tuned
    config (not the tuner's internals) and score against freshly
    computed exact ground truth — the acceptance bar is target - 0.02
    (timing noise never moves recall; the slack only covers query-draw
    variance)."""
    s, tuned, _, q = tuned_session
    emb_db = np.asarray(s._fit_emb)
    q_emb = s.model.embed(np.asarray(q))
    gt_ids, _ = ev.ground_truth(emb_db, np.asarray(q_emb), 10)
    idx = build_index(s.model.codes, s.model.C, s.model.structure,
                      index_cfg=tuned.index, serve_cfg=tuned.serve,
                      emb_db=s._fit_emb, key=jax.random.PRNGKey(0))
    res = idx.search(q_emb, 10)
    recall = ev.recall_at_k(np.asarray(res.indices)[:, :10], gt_ids, 10)
    assert recall >= 0.8 - 0.02


def test_tuned_config_round_trips_through_artifacts(tuned_session,
                                                    tmp_path):
    s, tuned, x, q = tuned_session
    s.save(str(tmp_path))
    s2 = ICQSession.from_artifacts(str(tmp_path))
    assert s2.config.config_hash() == tuned.config_hash()
    # the reloaded session serves with the tuned knobs bitwise (the
    # reloaded model re-encodes the db deterministically)
    r1 = s.index().search(q, k=10)
    r2 = s2.index(x).search(q, k=10)
    np.testing.assert_array_equal(np.asarray(r1.indices),
                                  np.asarray(r2.indices))


def test_tune_apply_false_leaves_session_untouched():
    x, y, q = _bundle_workload(seed=1)
    s = ICQSession(_cfg())
    s.fit(x, y)
    before = s.config.config_hash()
    nf_before = int(s.model.structure.fast_mask.sum())
    tuned = s.tune(queries=q, target_recall=0.8, k=10, repeats=1,
                   cache_dir=None, apply=False)
    assert s.config.config_hash() == before
    assert int(s.model.structure.fast_mask.sum()) == nf_before
    assert isinstance(tuned, ICQConfig)


def test_tune_guards():
    s = ICQSession(_cfg())
    with pytest.raises(ConfigError, match="before session.fit"):
        s.tune(queries=np.zeros((2, 16), np.float32))
    x, y, q = _bundle_workload(seed=2)
    s.fit(x, y)
    with pytest.raises(ConfigError, match="needs queries"):
        s.tune()


def test_tune_explicit_grid_and_unreachable_target():
    """CI-style reduced grid; an unreachable target falls back to the
    max-recall point and reports met_target=False."""
    x, y, q = _bundle_workload(seed=3)
    s = ICQSession(_cfg())
    s.fit(x, y)
    grid = [{"index.n_probe": 1}, {"index.n_probe": 4}]
    s.tune(queries=q, target_recall=1.1, k=10, grid=grid, repeats=1,
           cache_dir=None, apply=False)
    rep = s.last_tune
    assert rep["met_target"] is False
    assert rep["selected"]["recall"] == max(p["recall"]
                                            for p in rep["points"])
